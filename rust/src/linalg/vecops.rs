//! Vector primitives shared by the solvers and the screening engine.
//!
//! These are deliberately simple free functions over `&[f64]`; the hot loops
//! are written so that LLVM auto-vectorizes them (no bounds checks inside,
//! `chunks_exact` style accumulation where it matters).

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += x[0] * y[0];
        acc1 += x[1] * y[1];
        acc2 += x[2] * y[2];
        acc3 += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// Sum of entries.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    a.iter().sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = (1 - t) * y + t * x` (convex combination in place).
#[inline]
pub fn lerp_into(t: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = (1.0 - t) * *yi + t * xi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Maximum absolute difference between two vectors.
#[inline]
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Sum of negative parts: `Σ min(s_k, 0)` — the `s_-(V)` of Lemma 4.
#[inline]
pub fn sum_neg(a: &[f64]) -> f64 {
    a.iter().map(|x| x.min(0.0)).sum()
}

/// Indices sorted by value, descending; ties broken by index (ascending)
/// so the greedy ordering is deterministic. Delegates to
/// [`argsort_desc_into`] so every argsort in the crate uses the *same*
/// total order (bit-level: `-0.0` sorts before `+0.0`) and the adaptive
/// fast path stays bit-identical to this reference.
pub fn argsort_desc(w: &[f64]) -> Vec<usize> {
    let mut idx = Vec::new();
    argsort_desc_into(w, &mut idx);
    idx
}

/// IEEE-754 total-order key: doubles map to monotone u64 keys, so the
/// sort comparators below are branch-light integer compares (~2× faster
/// than `partial_cmp` — the argsort is on the per-iteration greedy path).
#[inline]
fn total_order_key(x: f64) -> u64 {
    let bits = x.to_bits();
    // Flip: negatives reverse, positives offset — total order.
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1 << 63)
    }
}

/// The (descending value, ascending index) sort rank of element `i`:
/// ascending on this tuple is exactly the deterministic greedy order.
#[inline]
fn desc_rank(w: &[f64], i: usize) -> (u64, usize) {
    (!total_order_key(w[i]), i)
}

/// Fill an existing index buffer with the descending argsort of `w`.
/// Avoids allocation on the solver hot path (the cold full-sort path of
/// [`argsort_desc_adaptive`]).
pub fn argsort_desc_into(w: &[f64], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..w.len());
    // Descending by value, ties ascending by index: sort ascending on
    // (!key, index).
    idx.sort_unstable_by_key(|&i| desc_rank(w, i));
}

/// Descending argsort that *reuses* the previous permutation in `idx`.
///
/// Between consecutive solver major iterations the direction vector moves
/// by one convex-combination step, so the previous greedy order is almost
/// sorted for the new vector. This fast path repairs it with a
/// budget-bounded insertion sort — O(p + inversions) — and falls back to
/// the full [`argsort_desc_into`] sort when `idx` has the wrong length
/// (fresh/resized workspace) or the repair budget is exhausted (the order
/// genuinely changed). The result is **always** the unique deterministic
/// greedy order (descending by value, ties ascending by index): both
/// paths sort by the same total order, so which path ran is unobservable.
///
/// `idx` must be a permutation of `0..w.len()` whenever its length
/// matches (it always is when the buffer is only written by this function
/// or [`argsort_desc_into`]).
pub fn argsort_desc_adaptive(w: &[f64], idx: &mut Vec<usize>) {
    let n = w.len();
    if idx.len() != n {
        argsort_desc_into(w, idx);
        return;
    }
    // Insertion repair: cheap when nearly sorted; bail to the full sort
    // once the shift work exceeds ~4 sweeps (a disordered input would
    // otherwise degrade to O(n²)).
    let budget = 4 * n + 16;
    let mut work = 0usize;
    for t in 1..n {
        let cur = idx[t];
        let rank_cur = desc_rank(w, cur);
        let mut s = t;
        while s > 0 && desc_rank(w, idx[s - 1]) > rank_cur {
            idx[s] = idx[s - 1];
            s -= 1;
            work += 1;
            if work > budget {
                idx[s] = cur; // restore the permutation, then full sort
                argsort_desc_into(w, idx);
                return;
            }
        }
        idx[s] = cur;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let a = [3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((norm1(&a) - 7.0).abs() < 1e-15);
        assert!((norm2_sq(&a) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn lerp_works() {
        let x = [0.0, 10.0];
        let mut y = [10.0, 0.0];
        lerp_into(0.25, &x, &mut y);
        assert_eq!(y, [7.5, 2.5]);
    }

    #[test]
    fn argsort_desc_with_ties() {
        let w = [1.0, 3.0, 3.0, -1.0];
        assert_eq!(argsort_desc(&w), vec![1, 2, 0, 3]);
        let mut buf = Vec::new();
        argsort_desc_into(&w, &mut buf);
        assert_eq!(buf, vec![1, 2, 0, 3]);
    }

    #[test]
    fn sum_neg_works() {
        assert_eq!(sum_neg(&[1.0, -2.0, 3.0, -0.5]), -2.5);
    }

    #[test]
    fn adaptive_argsort_matches_full_sort() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(314);
        let mut idx = Vec::new();
        for case in 0..200 {
            let n = 1 + rng.below(80);
            let mut w = rng.normal_vec(n);
            // Inject ties so the index tiebreak is exercised.
            if n > 4 {
                w[1] = w[0];
                w[n - 1] = w[n / 2];
            }
            // Warm path: perturb a previously sorted order slightly…
            argsort_desc_adaptive(&w, &mut idx);
            for (a, b) in argsort_desc(&w).iter().zip(&idx) {
                assert_eq!(a, b, "case {case} (cold/resized path)");
            }
            for round in 0..3 {
                // small drift: nearly sorted input for the repair path
                for x in w.iter_mut() {
                    *x += 0.05 * rng.normal();
                }
                argsort_desc_adaptive(&w, &mut idx);
                assert_eq!(idx, argsort_desc(&w), "case {case} round {round}");
            }
            // …and a complete reshuffle for the budget-bail path.
            for x in w.iter_mut() {
                *x = rng.normal();
            }
            argsort_desc_adaptive(&w, &mut idx);
            assert_eq!(idx, argsort_desc(&w), "case {case} (reshuffled)");
            // Different length next case forces the length-mismatch path.
            if rng.bernoulli(0.5) {
                idx.clear();
            }
        }
    }

    #[test]
    fn adaptive_argsort_handles_reversed_input() {
        // Fully reversed previous order: budget must trip, result exact.
        let n = 257;
        let w: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut idx: Vec<usize> = (0..n).collect(); // ascending = worst case
        argsort_desc_adaptive(&w, &mut idx);
        let expect: Vec<usize> = (0..n).rev().collect();
        assert_eq!(idx, expect);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }
}
