//! Vector primitives shared by the solvers and the screening engine.
//!
//! These are deliberately simple free functions over `&[f64]`; the hot loops
//! are written so that LLVM auto-vectorizes them (no bounds checks inside,
//! `chunks_exact` style accumulation where it matters).

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += x[0] * y[0];
        acc1 += x[1] * y[1];
        acc2 += x[2] * y[2];
        acc3 += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// Sum of entries.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    a.iter().sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = (1 - t) * y + t * x` (convex combination in place).
#[inline]
pub fn lerp_into(t: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = (1.0 - t) * *yi + t * xi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Maximum absolute difference between two vectors.
#[inline]
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Sum of negative parts: `Σ min(s_k, 0)` — the `s_-(V)` of Lemma 4.
#[inline]
pub fn sum_neg(a: &[f64]) -> f64 {
    a.iter().map(|x| x.min(0.0)).sum()
}

/// Indices sorted by value, descending; ties broken by index (ascending)
/// so the greedy ordering is deterministic. Delegates to
/// [`argsort_desc_into`] so every argsort in the crate uses the *same*
/// total order (bit-level: `-0.0` sorts before `+0.0`) and the adaptive
/// fast path stays bit-identical to this reference.
pub fn argsort_desc(w: &[f64]) -> Vec<usize> {
    let mut idx = Vec::new();
    argsort_desc_into(w, &mut idx);
    idx
}

/// IEEE-754 total-order key: doubles map to monotone u64 keys, so the
/// sort comparators below are branch-light integer compares (~2× faster
/// than `partial_cmp` — the argsort is on the per-iteration greedy path).
#[inline]
fn total_order_key(x: f64) -> u64 {
    let bits = x.to_bits();
    // Flip: negatives reverse, positives offset — total order.
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1 << 63)
    }
}

/// The (descending value, ascending index) sort rank of element `i`:
/// ascending on this tuple is exactly the deterministic greedy order.
#[inline]
fn desc_rank(w: &[f64], i: usize) -> (u64, usize) {
    (!total_order_key(w[i]), i)
}

/// Fill an existing index buffer with the descending argsort of `w`.
/// Avoids allocation on the solver hot path (the cold full-sort path of
/// [`argsort_desc_adaptive`]).
pub fn argsort_desc_into(w: &[f64], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..w.len());
    // Descending by value, ties ascending by index: sort ascending on
    // (!key, index).
    idx.sort_unstable_by_key(|&i| desc_rank(w, i));
}

/// Budget-bounded insertion repair of an almost-sorted permutation:
/// O(n + inversions), bailing out once the shift work exceeds ~4 sweeps
/// (a disordered input would otherwise degrade to O(n²)). Returns `true`
/// when `idx` is the exact deterministic greedy order on exit, `false`
/// when the budget tripped (`idx` is left a valid permutation either
/// way, so the caller can fall back to the full sort).
fn insertion_repair(w: &[f64], idx: &mut [usize]) -> bool {
    let n = idx.len();
    let budget = 4 * n + 16;
    let mut work = 0usize;
    for t in 1..n {
        let cur = idx[t];
        let rank_cur = desc_rank(w, cur);
        let mut s = t;
        while s > 0 && desc_rank(w, idx[s - 1]) > rank_cur {
            idx[s] = idx[s - 1];
            s -= 1;
            work += 1;
            if work > budget {
                idx[s] = cur; // restore the permutation for the caller
                return false;
            }
        }
        idx[s] = cur;
    }
    true
}

/// Descending argsort that *reuses* the previous permutation in `idx`.
///
/// Between consecutive solver major iterations the direction vector moves
/// by one convex-combination step, so the previous greedy order is almost
/// sorted for the new vector. This fast path repairs it with a
/// budget-bounded insertion sort — O(p + inversions) — and falls back to
/// the full [`argsort_desc_into`] sort when `idx` has the wrong length
/// (fresh/resized workspace) or the repair budget is exhausted (the order
/// genuinely changed). The result is **always** the unique deterministic
/// greedy order (descending by value, ties ascending by index): both
/// paths sort by the same total order, so which path ran is unobservable.
///
/// Returns `true` when the warm repair sufficed and `false` when a full
/// sort ran (solver workspaces count the latter for diagnostics).
///
/// `idx` must be a permutation of `0..w.len()` whenever its length
/// matches (it always is when the buffer is only written by this function
/// or [`argsort_desc_into`]).
pub fn argsort_desc_adaptive(w: &[f64], idx: &mut Vec<usize>) -> bool {
    if idx.len() != w.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    if insertion_repair(w, idx) {
        true
    } else {
        argsort_desc_into(w, idx);
        false
    }
}

/// Rewrite a stale index buffer through a survivor map in place:
/// entries whose `new_of_old` slot is `usize::MAX` (removed) are dropped,
/// surviving entries are replaced by their new indices, and relative
/// order is preserved. The filtering is O(len) and allocation-free.
pub fn project_indices(idx: &mut Vec<usize>, new_of_old: &[usize]) {
    let mut write = 0usize;
    for read in 0..idx.len() {
        let mapped = new_of_old[idx[read]];
        if mapped != usize::MAX {
            idx[write] = mapped;
            write += 1;
        }
    }
    idx.truncate(write);
}

/// Descending argsort warm-started through a ground-set contraction.
///
/// `idx` holds the greedy permutation of the *pre-contraction* vector
/// (length `new_of_old.len()`); `new_of_old[i]` gives element `i`'s index
/// in the contracted problem, or `usize::MAX` if it was removed. Because
/// the surviving elements keep their values and their relative ranks, the
/// survivors of the old order — mapped to new indices — are already the
/// sorted order of `w` up to tie-index drift, so an insertion repair
/// finishes the job in O(p) instead of a full O(p log p) re-sort (the
/// length-mismatch cold path this replaces).
///
/// Falls back to [`argsort_desc_into`] when the lengths don't line up or
/// the repair budget trips; like [`argsort_desc_adaptive`], the result is
/// always the unique deterministic greedy order, so which path ran is
/// unobservable bit for bit. Returns `true` iff the remap fast path
/// completed without a full sort.
pub fn argsort_desc_remap(w: &[f64], idx: &mut Vec<usize>, new_of_old: &[usize]) -> bool {
    if idx.len() != new_of_old.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    project_indices(idx, new_of_old);
    if idx.len() != w.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    if insertion_repair(w, idx) {
        true
    } else {
        argsort_desc_into(w, idx);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let a = [3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((norm1(&a) - 7.0).abs() < 1e-15);
        assert!((norm2_sq(&a) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn lerp_works() {
        let x = [0.0, 10.0];
        let mut y = [10.0, 0.0];
        lerp_into(0.25, &x, &mut y);
        assert_eq!(y, [7.5, 2.5]);
    }

    #[test]
    fn argsort_desc_with_ties() {
        let w = [1.0, 3.0, 3.0, -1.0];
        assert_eq!(argsort_desc(&w), vec![1, 2, 0, 3]);
        let mut buf = Vec::new();
        argsort_desc_into(&w, &mut buf);
        assert_eq!(buf, vec![1, 2, 0, 3]);
    }

    #[test]
    fn sum_neg_works() {
        assert_eq!(sum_neg(&[1.0, -2.0, 3.0, -0.5]), -2.5);
    }

    #[test]
    fn adaptive_argsort_matches_full_sort() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(314);
        let mut idx = Vec::new();
        for case in 0..200 {
            let n = 1 + rng.below(80);
            let mut w = rng.normal_vec(n);
            // Inject ties so the index tiebreak is exercised.
            if n > 4 {
                w[1] = w[0];
                w[n - 1] = w[n / 2];
            }
            // Warm path: perturb a previously sorted order slightly…
            argsort_desc_adaptive(&w, &mut idx);
            for (a, b) in argsort_desc(&w).iter().zip(&idx) {
                assert_eq!(a, b, "case {case} (cold/resized path)");
            }
            for round in 0..3 {
                // small drift: nearly sorted input for the repair path
                for x in w.iter_mut() {
                    *x += 0.05 * rng.normal();
                }
                argsort_desc_adaptive(&w, &mut idx);
                assert_eq!(idx, argsort_desc(&w), "case {case} round {round}");
            }
            // …and a complete reshuffle for the budget-bail path.
            for x in w.iter_mut() {
                *x = rng.normal();
            }
            argsort_desc_adaptive(&w, &mut idx);
            assert_eq!(idx, argsort_desc(&w), "case {case} (reshuffled)");
            // Different length next case forces the length-mismatch path.
            if rng.bernoulli(0.5) {
                idx.clear();
            }
        }
    }

    #[test]
    fn adaptive_argsort_handles_reversed_input() {
        // Fully reversed previous order: budget must trip, result exact.
        let n = 257;
        let w: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut idx: Vec<usize> = (0..n).collect(); // ascending = worst case
        argsort_desc_adaptive(&w, &mut idx);
        let expect: Vec<usize> = (0..n).rev().collect();
        assert_eq!(idx, expect);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }

    /// Drop every element of `w_old` whose index is in `drop`, returning
    /// the contracted vector and the old→new survivor map.
    fn contract_vec(w_old: &[f64], drop: &[usize]) -> (Vec<f64>, Vec<usize>) {
        let mut w_new = Vec::new();
        let mut map = vec![usize::MAX; w_old.len()];
        for (i, &x) in w_old.iter().enumerate() {
            if !drop.contains(&i) {
                map[i] = w_new.len();
                w_new.push(x);
            }
        }
        (w_new, map)
    }

    #[test]
    fn project_indices_filters_and_renumbers() {
        let map = [0usize, usize::MAX, 1, usize::MAX, 2];
        let mut idx = vec![4, 1, 0, 3, 2];
        project_indices(&mut idx, &map);
        assert_eq!(idx, vec![2, 0, 1]);
    }

    #[test]
    fn remap_takes_fast_path_after_contraction() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(2718);
        for case in 0..100 {
            let n = 5 + rng.below(120);
            let w_old = rng.normal_vec(n);
            let mut idx = argsort_desc(&w_old);
            // Drop a random ~25% of the elements.
            let drop: Vec<usize> = (0..n).filter(|_| rng.bernoulli(0.25)).collect();
            if drop.len() == n {
                continue;
            }
            let (w_new, map) = contract_vec(&w_old, &drop);
            let fast = argsort_desc_remap(&w_new, &mut idx, &map);
            assert!(fast, "case {case}: remap fell back to a full sort");
            assert_eq!(idx, argsort_desc(&w_new), "case {case}");
        }
    }

    #[test]
    fn remap_fast_path_survives_ties() {
        // Survivors keep relative ascending-index order inside value ties,
        // so the repair sees them already tie-broken correctly.
        let w_old = [2.0, 1.0, 2.0, 1.0, 2.0, 0.5];
        let mut idx = argsort_desc(&w_old); // [0,2,4,1,3,5]
        let (w_new, map) = contract_vec(&w_old, &[2]);
        assert!(argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn remap_falls_back_on_length_mismatch() {
        // Stale buffer from an unrelated problem: must cold-sort, exactly.
        let w_new = [3.0, 1.0, 2.0];
        let map = [0usize, 1, 2, usize::MAX]; // wrong old length vs idx
        let mut idx = vec![0, 1];
        assert!(!argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn remap_falls_back_when_survivor_count_disagrees() {
        // idx is not a full permutation of the old ground set (defensive):
        // the mapped length misses w.len() and the full sort must run.
        let w_new = [1.0, -1.0];
        let map = [0usize, usize::MAX, 1];
        let mut idx_bad = vec![1, 1, 1]; // every entry maps to "removed"
        assert!(!argsort_desc_remap(&w_new, &mut idx_bad, &map));
        assert_eq!(idx_bad, argsort_desc(&w_new));
        // A well-formed permutation still takes the fast path.
        let mut idx = vec![0, 2, 1];
        assert!(argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn adaptive_reports_path_taken() {
        let w = [1.0, 3.0, 2.0];
        let mut idx = Vec::new();
        assert!(!argsort_desc_adaptive(&w, &mut idx), "cold path must report");
        assert!(argsort_desc_adaptive(&w, &mut idx), "warm repair must report");
        assert_eq!(idx, argsort_desc(&w));
    }
}
