//! Vector primitives shared by the solvers and the screening engine.
//!
//! These are deliberately simple free functions over `&[f64]`; the hot loops
//! are written so that LLVM auto-vectorizes them (no bounds checks inside,
//! `chunks_exact` style accumulation where it matters).

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += x[0] * y[0];
        acc1 += x[1] * y[1];
        acc2 += x[2] * y[2];
        acc3 += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// Sum of entries.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    a.iter().sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = (1 - t) * y + t * x` (convex combination in place).
#[inline]
pub fn lerp_into(t: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = (1.0 - t) * *yi + t * xi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Maximum absolute difference between two vectors.
#[inline]
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Sum of negative parts: `Σ min(s_k, 0)` — the `s_-(V)` of Lemma 4.
#[inline]
pub fn sum_neg(a: &[f64]) -> f64 {
    a.iter().map(|x| x.min(0.0)).sum()
}

/// Indices sorted by value, descending; ties broken by index (ascending)
/// so the greedy ordering is deterministic.
pub fn argsort_desc(w: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..w.len()).collect();
    idx.sort_by(|&a, &b| {
        w[b].partial_cmp(&w[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// Fill an existing index buffer with the descending argsort of `w`.
/// Avoids allocation on the solver hot path.
///
/// Sorting uses the total-order bit trick (IEEE-754 doubles map to
/// monotone u64 keys), which is ~2× faster than a `partial_cmp`
/// comparator — the argsort is on the per-iteration greedy path.
pub fn argsort_desc_into(w: &[f64], idx: &mut Vec<usize>) {
    #[inline]
    fn key(x: f64) -> u64 {
        let bits = x.to_bits();
        // Flip: negatives reverse, positives offset — total order.
        if bits >> 63 == 1 {
            !bits
        } else {
            bits | (1 << 63)
        }
    }
    idx.clear();
    idx.extend(0..w.len());
    // Descending by value, ties ascending by index: sort ascending on
    // (!key, index).
    idx.sort_unstable_by_key(|&i| (!key(w[i]), i));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let a = [3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((norm1(&a) - 7.0).abs() < 1e-15);
        assert!((norm2_sq(&a) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn lerp_works() {
        let x = [0.0, 10.0];
        let mut y = [10.0, 0.0];
        lerp_into(0.25, &x, &mut y);
        assert_eq!(y, [7.5, 2.5]);
    }

    #[test]
    fn argsort_desc_with_ties() {
        let w = [1.0, 3.0, 3.0, -1.0];
        assert_eq!(argsort_desc(&w), vec![1, 2, 0, 3]);
        let mut buf = Vec::new();
        argsort_desc_into(&w, &mut buf);
        assert_eq!(buf, vec![1, 2, 0, 3]);
    }

    #[test]
    fn sum_neg_works() {
        assert_eq!(sum_neg(&[1.0, -2.0, 3.0, -0.5]), -2.5);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }
}
