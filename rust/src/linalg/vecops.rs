//! Vector primitives shared by the solvers and the screening engine.
//!
//! These are deliberately simple free functions over `&[f64]`; the hot loops
//! are written so that LLVM auto-vectorizes them (no bounds checks inside,
//! `chunks_exact` style accumulation where it matters).

/// Dot product — delegates to the explicit 4-lane kernel [`dot4`].
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot4(a, b)
}

/// Explicitly 4-lane-unrolled dot product: four independent accumulators
/// over `chunks_exact(4)` (LLVM turns this into packed FMA/mul-add
/// lanes), remainder in a scalar tail, lanes reduced as
/// `a0 + a1 + a2 + a3 + tail`. The summation tree is fixed — the result
/// is a pure function of the inputs, never of how the call is scheduled.
#[inline]
pub fn dot4(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += x[0] * y[0];
        acc1 += x[1] * y[1];
        acc2 += x[2] * y[2];
        acc3 += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Gathered multiply-accumulate `Σ_k w[k] · table[idx[k]]` — the sparse
/// cut adjacency walk (`w` = edge weights, `idx` = neighbor ids, `table`
/// = 0/1 membership). Same 4-lane structure and fixed reduction tree as
/// [`dot4`], so chunked callers get bitwise thread-count-independent
/// partials.
#[inline]
pub fn dot_gather4(w: &[f64], idx: &[u32], table: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), idx.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut cw = w.chunks_exact(4);
    let mut ci = idx.chunks_exact(4);
    for (x, j) in (&mut cw).zip(&mut ci) {
        acc0 += x[0] * table[j[0] as usize];
        acc1 += x[1] * table[j[1] as usize];
        acc2 += x[2] * table[j[2] as usize];
        acc3 += x[3] * table[j[3] as usize];
    }
    let mut tail = 0.0;
    for (x, j) in cw.remainder().iter().zip(ci.remainder()) {
        tail += x * table[*j as usize];
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// Sum of entries.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    a.iter().sum()
}

/// `y += alpha * x` — delegates to the explicit 4-lane kernel [`axpy4`].
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy4(alpha, x, y)
}

/// Explicitly 4-lane-unrolled `y += alpha * x`. Element-wise (no
/// reduction), so the unroll is bit-identical to the scalar loop.
#[inline]
pub fn axpy4(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let mut cy = y.chunks_exact_mut(4);
    let mut cx = x.chunks_exact(4);
    for (yv, xv) in (&mut cy).zip(&mut cx) {
        yv[0] += alpha * xv[0];
        yv[1] += alpha * xv[1];
        yv[2] += alpha * xv[2];
        yv[3] += alpha * xv[3];
    }
    for (yi, xi) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yi += alpha * xi;
    }
}

/// `y[i] += x[i]`, 4-lane unrolled. Element-wise, bit-identical to the
/// scalar loop — the row-accumulation kernel of the dense cut oracles.
#[inline]
pub fn add_assign4(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(x.len(), y.len());
    let mut cy = y.chunks_exact_mut(4);
    let mut cx = x.chunks_exact(4);
    for (yv, xv) in (&mut cy).zip(&mut cx) {
        yv[0] += xv[0];
        yv[1] += xv[1];
        yv[2] += xv[2];
        yv[3] += xv[3];
    }
    for (yi, xi) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yi += xi;
    }
}

/// Fused 4-row accumulator block sweep:
/// `acc[j] += (r0[j] + r1[j]) + (r2[j] + r3[j])` for every `j`.
///
/// This is the bandwidth-bound inner kernel of the dense kernel-cut
/// greedy pass — one sweep reads `acc` once per four rows instead of
/// once per row. The per-element expression (including the pairwise
/// parenthesization) is part of the oracle's bit-exact contract: the
/// pooled column-chunked sweep and the sequential sweep both evaluate
/// exactly this expression per element, which is why they agree bit for
/// bit at every thread count.
#[inline]
pub fn sweep4(acc: &mut [f64], r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64]) {
    let n = acc.len();
    debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
    let mut ca = acc.chunks_exact_mut(4);
    let mut c0 = r0.chunks_exact(4);
    let mut c1 = r1.chunks_exact(4);
    let mut c2 = r2.chunks_exact(4);
    let mut c3 = r3.chunks_exact(4);
    for ((((a, x0), x1), x2), x3) in
        (&mut ca).zip(&mut c0).zip(&mut c1).zip(&mut c2).zip(&mut c3)
    {
        a[0] += (x0[0] + x1[0]) + (x2[0] + x3[0]);
        a[1] += (x0[1] + x1[1]) + (x2[1] + x3[1]);
        a[2] += (x0[2] + x1[2]) + (x2[2] + x3[2]);
        a[3] += (x0[3] + x1[3]) + (x2[3] + x3[3]);
    }
    for ((((a, x0), x1), x2), x3) in ca
        .into_remainder()
        .iter_mut()
        .zip(c0.remainder())
        .zip(c1.remainder())
        .zip(c2.remainder())
        .zip(c3.remainder())
    {
        *a += (x0 + x1) + (x2 + x3);
    }
}

/// Coverage-gain kernel: for each item id `u` in `ids`, add `item_w[u]`
/// to the gain iff `covered[u]` is still false, and mark it covered.
/// Branchless (mask multiply) and 4-lane unrolled with the [`dot4`]
/// reduction tree.
///
/// `ids` must not contain duplicates — the flags are read per lane
/// before being written, so a repeated id inside one call would be
/// counted twice (a set never contains an item twice; `CoverageFn`
/// asserts this at construction).
#[inline]
pub fn cover_gain4(ids: &[u32], item_w: &[f64], covered: &mut [bool]) -> f64 {
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut ci = ids.chunks_exact(4);
    for j in &mut ci {
        let (u0, u1, u2, u3) =
            (j[0] as usize, j[1] as usize, j[2] as usize, j[3] as usize);
        acc0 += item_w[u0] * (!covered[u0] as u8 as f64);
        acc1 += item_w[u1] * (!covered[u1] as u8 as f64);
        acc2 += item_w[u2] * (!covered[u2] as u8 as f64);
        acc3 += item_w[u3] * (!covered[u3] as u8 as f64);
        covered[u0] = true;
        covered[u1] = true;
        covered[u2] = true;
        covered[u3] = true;
    }
    let mut tail = 0.0;
    for &u in ci.remainder() {
        let u = u as usize;
        tail += item_w[u] * (!covered[u] as u8 as f64);
        covered[u] = true;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// Facility-location gain kernel over one facility column: for each
/// client `u`, `gain += w[u] · max(s_u − cur[u], 0)` and
/// `cur[u] ← max(cur[u], s_u)`, where `s_u = scores[u · stride + col]`.
/// Branchless (relu + max) and 4-lane unrolled with the [`dot4`]
/// reduction tree; the strided gather keeps the clients × facilities
/// matrix layout unchanged.
#[inline]
pub fn relu_mac_col4(
    cur: &mut [f64],
    w: &[f64],
    scores: &[f64],
    col: usize,
    stride: usize,
) -> f64 {
    let n = cur.len();
    debug_assert_eq!(w.len(), n);
    debug_assert!(n == 0 || (n - 1) * stride + col < scores.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let mut u = 0;
    while u + 4 <= n {
        let s0 = scores[u * stride + col];
        let s1 = scores[(u + 1) * stride + col];
        let s2 = scores[(u + 2) * stride + col];
        let s3 = scores[(u + 3) * stride + col];
        acc0 += w[u] * (s0 - cur[u]).max(0.0);
        acc1 += w[u + 1] * (s1 - cur[u + 1]).max(0.0);
        acc2 += w[u + 2] * (s2 - cur[u + 2]).max(0.0);
        acc3 += w[u + 3] * (s3 - cur[u + 3]).max(0.0);
        cur[u] = cur[u].max(s0);
        cur[u + 1] = cur[u + 1].max(s1);
        cur[u + 2] = cur[u + 2].max(s2);
        cur[u + 3] = cur[u + 3].max(s3);
        u += 4;
    }
    let mut tail = 0.0;
    while u < n {
        let s = scores[u * stride + col];
        tail += w[u] * (s - cur[u]).max(0.0);
        cur[u] = cur[u].max(s);
        u += 1;
    }
    acc0 + acc1 + acc2 + acc3 + tail
}

/// `cur[u] ← max(cur[u], scores[u · stride + col])` — the base-set arm
/// of the facility oracle (no gain accumulation). Element-wise, 4-lane
/// unrolled.
#[inline]
pub fn max_update_col4(cur: &mut [f64], scores: &[f64], col: usize, stride: usize) {
    let n = cur.len();
    debug_assert!(n == 0 || (n - 1) * stride + col < scores.len());
    let mut u = 0;
    while u + 4 <= n {
        cur[u] = cur[u].max(scores[u * stride + col]);
        cur[u + 1] = cur[u + 1].max(scores[(u + 1) * stride + col]);
        cur[u + 2] = cur[u + 2].max(scores[(u + 2) * stride + col]);
        cur[u + 3] = cur[u + 3].max(scores[(u + 3) * stride + col]);
        u += 4;
    }
    while u < n {
        cur[u] = cur[u].max(scores[u * stride + col]);
        u += 1;
    }
}

/// `y = (1 - t) * y + t * x` (convex combination in place).
#[inline]
pub fn lerp_into(t: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = (1.0 - t) * *yi + t * xi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Maximum absolute difference between two vectors.
#[inline]
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Sum of negative parts: `Σ min(s_k, 0)` — the `s_-(V)` of Lemma 4.
#[inline]
pub fn sum_neg(a: &[f64]) -> f64 {
    a.iter().map(|x| x.min(0.0)).sum()
}

/// Indices sorted by value, descending; ties broken by index (ascending)
/// so the greedy ordering is deterministic. Delegates to
/// [`argsort_desc_into`] so every argsort in the crate uses the *same*
/// total order (bit-level: `-0.0` sorts before `+0.0`) and the adaptive
/// fast path stays bit-identical to this reference.
pub fn argsort_desc(w: &[f64]) -> Vec<usize> {
    let mut idx = Vec::new();
    argsort_desc_into(w, &mut idx);
    idx
}

/// IEEE-754 total-order key: doubles map to monotone u64 keys, so the
/// sort comparators below are branch-light integer compares (~2× faster
/// than `partial_cmp` — the argsort is on the per-iteration greedy path).
#[inline]
fn total_order_key(x: f64) -> u64 {
    let bits = x.to_bits();
    // Flip: negatives reverse, positives offset — total order.
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1 << 63)
    }
}

/// The (descending value, ascending index) sort rank of element `i`:
/// ascending on this tuple is exactly the deterministic greedy order.
#[inline]
fn desc_rank(w: &[f64], i: usize) -> (u64, usize) {
    (!total_order_key(w[i]), i)
}

/// Fill an existing index buffer with the descending argsort of `w`.
/// Avoids allocation on the solver hot path (the cold full-sort path of
/// [`argsort_desc_adaptive`]).
pub fn argsort_desc_into(w: &[f64], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..w.len());
    // Descending by value, ties ascending by index: sort ascending on
    // (!key, index).
    idx.sort_unstable_by_key(|&i| desc_rank(w, i));
}

/// Budget-bounded insertion repair of an almost-sorted permutation:
/// O(n + inversions), bailing out once the shift work exceeds ~4 sweeps
/// (a disordered input would otherwise degrade to O(n²)). Returns `true`
/// when `idx` is the exact deterministic greedy order on exit, `false`
/// when the budget tripped (`idx` is left a valid permutation either
/// way, so the caller can fall back to the full sort).
fn insertion_repair(w: &[f64], idx: &mut [usize]) -> bool {
    let n = idx.len();
    let budget = 4 * n + 16;
    let mut work = 0usize;
    for t in 1..n {
        let cur = idx[t];
        let rank_cur = desc_rank(w, cur);
        let mut s = t;
        while s > 0 && desc_rank(w, idx[s - 1]) > rank_cur {
            idx[s] = idx[s - 1];
            s -= 1;
            work += 1;
            if work > budget {
                idx[s] = cur; // restore the permutation for the caller
                return false;
            }
        }
        idx[s] = cur;
    }
    true
}

/// Descending argsort that *reuses* the previous permutation in `idx`.
///
/// Between consecutive solver major iterations the direction vector moves
/// by one convex-combination step, so the previous greedy order is almost
/// sorted for the new vector. This fast path repairs it with a
/// budget-bounded insertion sort — O(p + inversions) — and falls back to
/// the full [`argsort_desc_into`] sort when `idx` has the wrong length
/// (fresh/resized workspace) or the repair budget is exhausted (the order
/// genuinely changed). The result is **always** the unique deterministic
/// greedy order (descending by value, ties ascending by index): both
/// paths sort by the same total order, so which path ran is unobservable.
///
/// Returns `true` when the warm repair sufficed and `false` when a full
/// sort ran (solver workspaces count the latter for diagnostics).
///
/// `idx` must be a permutation of `0..w.len()` whenever its length
/// matches (it always is when the buffer is only written by this function
/// or [`argsort_desc_into`]).
pub fn argsort_desc_adaptive(w: &[f64], idx: &mut Vec<usize>) -> bool {
    if idx.len() != w.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    if insertion_repair(w, idx) {
        true
    } else {
        argsort_desc_into(w, idx);
        false
    }
}

/// Rewrite a stale index buffer through a survivor map in place:
/// entries whose `new_of_old` slot is `usize::MAX` (removed) are dropped,
/// surviving entries are replaced by their new indices, and relative
/// order is preserved. The filtering is O(len) and allocation-free.
pub fn project_indices(idx: &mut Vec<usize>, new_of_old: &[usize]) {
    let mut write = 0usize;
    for read in 0..idx.len() {
        let mapped = new_of_old[idx[read]];
        if mapped != usize::MAX {
            idx[write] = mapped;
            write += 1;
        }
    }
    idx.truncate(write);
}

/// Descending argsort warm-started through a ground-set contraction.
///
/// `idx` holds the greedy permutation of the *pre-contraction* vector
/// (length `new_of_old.len()`); `new_of_old[i]` gives element `i`'s index
/// in the contracted problem, or `usize::MAX` if it was removed. Because
/// the surviving elements keep their values and their relative ranks, the
/// survivors of the old order — mapped to new indices — are already the
/// sorted order of `w` up to tie-index drift, so an insertion repair
/// finishes the job in O(p) instead of a full O(p log p) re-sort (the
/// length-mismatch cold path this replaces).
///
/// Falls back to [`argsort_desc_into`] when the lengths don't line up or
/// the repair budget trips; like [`argsort_desc_adaptive`], the result is
/// always the unique deterministic greedy order, so which path ran is
/// unobservable bit for bit. Returns `true` iff the remap fast path
/// completed without a full sort.
pub fn argsort_desc_remap(w: &[f64], idx: &mut Vec<usize>, new_of_old: &[usize]) -> bool {
    if idx.len() != new_of_old.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    project_indices(idx, new_of_old);
    if idx.len() != w.len() {
        argsort_desc_into(w, idx);
        return false;
    }
    if insertion_repair(w, idx) {
        true
    } else {
        argsort_desc_into(w, idx);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let a = [3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((norm1(&a) - 7.0).abs() < 1e-15);
        assert!((norm2_sq(&a) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn axpy4_matches_scalar_bitwise() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(99);
        for n in [0usize, 1, 3, 4, 7, 8, 13, 64] {
            let x = rng.normal_vec(n);
            let mut y = rng.normal_vec(n);
            let mut y_ref = y.clone();
            axpy4(0.37, &x, &mut y);
            for (yi, xi) in y_ref.iter_mut().zip(&x) {
                *yi += 0.37 * xi;
            }
            for (a, b) in y.iter().zip(&y_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn add_assign4_matches_scalar_bitwise() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(100);
        for n in [0usize, 2, 4, 9, 33] {
            let x = rng.normal_vec(n);
            let mut y = rng.normal_vec(n);
            let mut y_ref = y.clone();
            add_assign4(&mut y, &x);
            for (yi, xi) in y_ref.iter_mut().zip(&x) {
                *yi += xi;
            }
            for (a, b) in y.iter().zip(&y_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn sweep4_matches_per_element_expression_bitwise() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(101);
        for n in [0usize, 1, 4, 6, 17, 40] {
            let r0 = rng.normal_vec(n);
            let r1 = rng.normal_vec(n);
            let r2 = rng.normal_vec(n);
            let r3 = rng.normal_vec(n);
            let mut acc = rng.normal_vec(n);
            let mut acc_ref = acc.clone();
            sweep4(&mut acc, &r0, &r1, &r2, &r3);
            for j in 0..n {
                acc_ref[j] += (r0[j] + r1[j]) + (r2[j] + r3[j]);
            }
            for (a, b) in acc.iter().zip(&acc_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn dot_gather4_matches_dot4_on_identity_gather() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(102);
        for n in [0usize, 3, 4, 11, 32] {
            let w = rng.normal_vec(n);
            let table = rng.normal_vec(n);
            let idx: Vec<u32> = (0..n as u32).collect();
            let a = dot_gather4(&w, &idx, &table);
            let b = dot4(&w, &table);
            assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
        }
        // And a genuine permuted gather against the naive reference
        // (same 4-lane reduction tree, computed by hand).
        let w = [1.0, 2.0, 3.0, 4.0, 5.0];
        let table = [10.0, 20.0, 30.0];
        let idx = [2u32, 0, 1, 2, 0];
        let expect = (1.0 * 30.0) + (2.0 * 10.0) + (3.0 * 20.0) + (4.0 * 30.0)
            + (5.0 * 10.0);
        assert!((dot_gather4(&w, &idx, &table) - expect).abs() < 1e-12);
    }

    #[test]
    fn cover_gain4_counts_each_item_once_and_marks() {
        let item_w = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let mut covered = vec![false, true, false, false, true, false];
        // 6 ids → one exact chunk of 4 plus a tail of 2.
        let ids = [0u32, 1, 2, 3, 4, 5];
        let gain = cover_gain4(&ids, &item_w, &mut covered);
        assert_eq!(gain, 1.0 + 4.0 + 8.0 + 32.0);
        assert!(covered.iter().all(|&c| c));
        // Second call: everything covered, zero gain.
        assert_eq!(cover_gain4(&ids, &item_w, &mut covered), 0.0);
    }

    #[test]
    fn relu_mac_col4_matches_branchy_reference() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(103);
        for clients in [0usize, 1, 4, 5, 9, 21] {
            let stride = 7;
            let col = 3;
            let scores = rng.uniform_vec(clients * stride, 0.0, 1.0);
            let w = rng.uniform_vec(clients, 0.0, 1.0);
            let mut cur = rng.uniform_vec(clients, 0.0, 1.0);
            let mut cur_ref = cur.clone();
            let gain = relu_mac_col4(&mut cur, &w, &scores, col, stride);
            let mut expect = 0.0;
            for u in 0..clients {
                let s = scores[u * stride + col];
                if s > cur_ref[u] {
                    expect += w[u] * (s - cur_ref[u]);
                    cur_ref[u] = s;
                }
            }
            assert!((gain - expect).abs() < 1e-12, "clients={clients}");
            for (a, b) in cur.iter().zip(&cur_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "clients={clients}");
            }
        }
    }

    #[test]
    fn max_update_col4_matches_branchy_reference() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(104);
        let clients = 13;
        let stride = 5;
        let scores = rng.uniform_vec(clients * stride, 0.0, 1.0);
        let mut cur = rng.uniform_vec(clients, 0.0, 1.0);
        let mut cur_ref = cur.clone();
        max_update_col4(&mut cur, &scores, 2, stride);
        for u in 0..clients {
            let s = scores[u * stride + 2];
            if s > cur_ref[u] {
                cur_ref[u] = s;
            }
        }
        assert_eq!(cur, cur_ref);
    }

    #[test]
    fn lerp_works() {
        let x = [0.0, 10.0];
        let mut y = [10.0, 0.0];
        lerp_into(0.25, &x, &mut y);
        assert_eq!(y, [7.5, 2.5]);
    }

    #[test]
    fn argsort_desc_with_ties() {
        let w = [1.0, 3.0, 3.0, -1.0];
        assert_eq!(argsort_desc(&w), vec![1, 2, 0, 3]);
        let mut buf = Vec::new();
        argsort_desc_into(&w, &mut buf);
        assert_eq!(buf, vec![1, 2, 0, 3]);
    }

    #[test]
    fn sum_neg_works() {
        assert_eq!(sum_neg(&[1.0, -2.0, 3.0, -0.5]), -2.5);
    }

    #[test]
    fn adaptive_argsort_matches_full_sort() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(314);
        let mut idx = Vec::new();
        for case in 0..200 {
            let n = 1 + rng.below(80);
            let mut w = rng.normal_vec(n);
            // Inject ties so the index tiebreak is exercised.
            if n > 4 {
                w[1] = w[0];
                w[n - 1] = w[n / 2];
            }
            // Warm path: perturb a previously sorted order slightly…
            argsort_desc_adaptive(&w, &mut idx);
            for (a, b) in argsort_desc(&w).iter().zip(&idx) {
                assert_eq!(a, b, "case {case} (cold/resized path)");
            }
            for round in 0..3 {
                // small drift: nearly sorted input for the repair path
                for x in w.iter_mut() {
                    *x += 0.05 * rng.normal();
                }
                argsort_desc_adaptive(&w, &mut idx);
                assert_eq!(idx, argsort_desc(&w), "case {case} round {round}");
            }
            // …and a complete reshuffle for the budget-bail path.
            for x in w.iter_mut() {
                *x = rng.normal();
            }
            argsort_desc_adaptive(&w, &mut idx);
            assert_eq!(idx, argsort_desc(&w), "case {case} (reshuffled)");
            // Different length next case forces the length-mismatch path.
            if rng.bernoulli(0.5) {
                idx.clear();
            }
        }
    }

    #[test]
    fn adaptive_argsort_handles_reversed_input() {
        // Fully reversed previous order: budget must trip, result exact.
        let n = 257;
        let w: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut idx: Vec<usize> = (0..n).collect(); // ascending = worst case
        argsort_desc_adaptive(&w, &mut idx);
        let expect: Vec<usize> = (0..n).rev().collect();
        assert_eq!(idx, expect);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }

    /// Drop every element of `w_old` whose index is in `drop`, returning
    /// the contracted vector and the old→new survivor map.
    fn contract_vec(w_old: &[f64], drop: &[usize]) -> (Vec<f64>, Vec<usize>) {
        let mut w_new = Vec::new();
        let mut map = vec![usize::MAX; w_old.len()];
        for (i, &x) in w_old.iter().enumerate() {
            if !drop.contains(&i) {
                map[i] = w_new.len();
                w_new.push(x);
            }
        }
        (w_new, map)
    }

    #[test]
    fn project_indices_filters_and_renumbers() {
        let map = [0usize, usize::MAX, 1, usize::MAX, 2];
        let mut idx = vec![4, 1, 0, 3, 2];
        project_indices(&mut idx, &map);
        assert_eq!(idx, vec![2, 0, 1]);
    }

    #[test]
    fn remap_takes_fast_path_after_contraction() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(2718);
        for case in 0..100 {
            let n = 5 + rng.below(120);
            let w_old = rng.normal_vec(n);
            let mut idx = argsort_desc(&w_old);
            // Drop a random ~25% of the elements.
            let drop: Vec<usize> = (0..n).filter(|_| rng.bernoulli(0.25)).collect();
            if drop.len() == n {
                continue;
            }
            let (w_new, map) = contract_vec(&w_old, &drop);
            let fast = argsort_desc_remap(&w_new, &mut idx, &map);
            assert!(fast, "case {case}: remap fell back to a full sort");
            assert_eq!(idx, argsort_desc(&w_new), "case {case}");
        }
    }

    #[test]
    fn remap_fast_path_survives_ties() {
        // Survivors keep relative ascending-index order inside value ties,
        // so the repair sees them already tie-broken correctly.
        let w_old = [2.0, 1.0, 2.0, 1.0, 2.0, 0.5];
        let mut idx = argsort_desc(&w_old); // [0,2,4,1,3,5]
        let (w_new, map) = contract_vec(&w_old, &[2]);
        assert!(argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn remap_falls_back_on_length_mismatch() {
        // Stale buffer from an unrelated problem: must cold-sort, exactly.
        let w_new = [3.0, 1.0, 2.0];
        let map = [0usize, 1, 2, usize::MAX]; // wrong old length vs idx
        let mut idx = vec![0, 1];
        assert!(!argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn remap_falls_back_when_survivor_count_disagrees() {
        // idx is not a full permutation of the old ground set (defensive):
        // the mapped length misses w.len() and the full sort must run.
        let w_new = [1.0, -1.0];
        let map = [0usize, usize::MAX, 1];
        let mut idx_bad = vec![1, 1, 1]; // every entry maps to "removed"
        assert!(!argsort_desc_remap(&w_new, &mut idx_bad, &map));
        assert_eq!(idx_bad, argsort_desc(&w_new));
        // A well-formed permutation still takes the fast path.
        let mut idx = vec![0, 2, 1];
        assert!(argsort_desc_remap(&w_new, &mut idx, &map));
        assert_eq!(idx, argsort_desc(&w_new));
    }

    #[test]
    fn adaptive_reports_path_taken() {
        let w = [1.0, 3.0, 2.0];
        let mut idx = Vec::new();
        assert!(!argsort_desc_adaptive(&w, &mut idx), "cold path must report");
        assert!(argsort_desc_adaptive(&w, &mut idx), "warm repair must report");
        assert_eq!(idx, argsort_desc(&w));
    }
}
