//! Small dense linear-algebra toolkit.
//!
//! The library needs exactly three things from linear algebra:
//!
//! 1. Cholesky factorization + triangular solves (min-norm-point affine
//!    minimization over the corral Gram matrix),
//! 2. *incrementally extended* Cholesky factors (GP log-determinants along
//!    nested prefix sets for the Gaussian mutual-information oracle, and
//!    rank-one corral updates in the optimized min-norm solver),
//! 3. basic vector operations used across solvers and screening.
//!
//! No external BLAS: the corral dimension is small (≤ a few hundred) and the
//! GP kernels are ≤ a few thousand, so straightforward cache-friendly loops
//! are adequate and keep the build fully offline.

pub mod cholesky;
pub mod vecops;

pub use cholesky::{Cholesky, IncrementalCholesky};
pub use vecops::*;

/// Dense row-major matrix, minimal by design.
#[derive(Clone, Debug)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows * cols` entries.
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix–vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = vecops::dot(self.row(i), x);
        }
        y
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Symmetrize in place: `A <- (A + A^T) / 2`. Requires square.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

/// Flat row-major storage for a dynamically sized set of fixed-length
/// rows — the min-norm corral and the Frank–Wolfe atom set.
///
/// Replaces `Vec<Vec<f64>>`: rows live contiguously (`Vec<f64>` + stride),
/// so iterating vertices streams memory instead of chasing pointers, and
/// `push`/`remove` reuse the high-water capacity — steady-state solver
/// iterations perform zero heap allocations. Removal is order-preserving
/// (a contiguous `memmove`), matching the index bookkeeping of
/// [`IncrementalCholesky::remove`].
#[derive(Clone, Debug, Default)]
pub struct CorralMat {
    data: Vec<f64>,
    stride: usize,
    rows: usize,
}

impl CorralMat {
    /// Empty matrix with rows of length `stride`.
    pub fn new(stride: usize) -> Self {
        CorralMat { data: Vec::new(), stride, rows: 0 }
    }

    /// Number of rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows
    }

    /// True when there are no rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row length.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// Mutably borrow row `i` (the contraction-restart path regenerates
    /// projected vertices in place).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// Shrink the row length to `new_stride`, keeping the row *count*.
    /// Row contents are left unspecified (the caller overwrites every row
    /// right after — this is the projected-corral restart, which
    /// regenerates each vertex at the contracted size); capacity is
    /// retained, so no allocation ever happens here.
    pub fn reshape_rows(&mut self, new_stride: usize) {
        assert!(new_stride <= self.stride, "reshape_rows can only shrink");
        self.stride = new_stride;
        self.data.truncate(self.rows * new_stride);
    }

    /// Append a row (copied into the flat storage; amortized
    /// allocation-free once the high-water capacity is reached).
    pub fn push(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.stride, "row length mismatch");
        self.data.extend_from_slice(v);
        self.rows += 1;
    }

    /// Remove row `i`, preserving the order of the remaining rows
    /// (contiguous in-place `memmove`; capacity retained).
    pub fn remove(&mut self, i: usize) {
        assert!(i < self.rows);
        let s = self.stride;
        self.data.copy_within((i + 1) * s.., i * s);
        self.rows -= 1;
        self.data.truncate(self.rows * s);
    }

    /// Keep only the rows at the (ascending, unique) indices in `keep`.
    pub fn compact(&mut self, keep: &[usize]) {
        let s = self.stride;
        for (w, &r) in keep.iter().enumerate() {
            debug_assert!(w <= r && r < self.rows);
            if w != r {
                self.data.copy_within(r * s..(r + 1) * s, w * s);
            }
        }
        self.rows = keep.len();
        self.data.truncate(self.rows * s);
    }

    /// Drop all rows and (if needed) change the row length; capacity is
    /// retained for reuse across solver warm-restarts.
    pub fn reset(&mut self, stride: usize) {
        self.data.clear();
        self.stride = stride;
        self.rows = 0;
    }

    /// Iterate rows as slices.
    pub fn iter(&self) -> impl Iterator<Item = &[f64]> {
        // `max(1)`: chunks_exact panics on 0; a default-constructed
        // (stride 0) matrix has no data and yields nothing either way.
        self.data.chunks_exact(self.stride.max(1))
    }
}

/// Flat row-major storage for a dynamically sized set of fixed-length
/// *index* rows — the generating greedy permutation of each min-norm
/// corral vertex (and, structurally, any per-atom id list).
///
/// Mirrors [`CorralMat`]'s push/remove/compact/reset contract so the two
/// stay in lockstep as parallel arrays, and adds [`contract`]: rewriting
/// every stored permutation through an IAES survivor map in one in-place
/// sweep, which is what lets a contraction *project* the corral instead
/// of discarding it.
///
/// [`contract`]: IndexMat::contract
#[derive(Clone, Debug, Default)]
pub struct IndexMat {
    data: Vec<usize>,
    stride: usize,
    rows: usize,
}

impl IndexMat {
    /// Empty matrix with rows of length `stride`.
    pub fn new(stride: usize) -> Self {
        IndexMat { data: Vec::new(), stride, rows: 0 }
    }

    /// Number of rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows
    }

    /// True when there are no rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row length.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[usize] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// Append a row (amortized allocation-free at the high-water mark).
    pub fn push(&mut self, ids: &[usize]) {
        assert_eq!(ids.len(), self.stride, "row length mismatch");
        self.data.extend_from_slice(ids);
        self.rows += 1;
    }

    /// Keep only the rows at the (ascending, unique) indices in `keep`.
    pub fn compact(&mut self, keep: &[usize]) {
        let s = self.stride;
        for (w, &r) in keep.iter().enumerate() {
            debug_assert!(w <= r && r < self.rows);
            if w != r {
                self.data.copy_within(r * s..(r + 1) * s, w * s);
            }
        }
        self.rows = keep.len();
        self.data.truncate(self.rows * s);
    }

    /// Drop all rows and (if needed) change the row length; capacity is
    /// retained for reuse across solver warm-restarts.
    pub fn reset(&mut self, stride: usize) {
        self.data.clear();
        self.stride = stride;
        self.rows = 0;
    }

    /// Rewrite every row through an IAES survivor map: entries with
    /// `new_of_old[e] == usize::MAX` are dropped, the rest renumbered, in
    /// one in-place front-to-back sweep (write never overtakes read since
    /// `new_stride <= stride`). Every row must be a full permutation of
    /// the old ground set, so each contracts to exactly `new_stride`
    /// surviving entries — the induced greedy order on the contracted
    /// problem.
    pub fn contract(&mut self, new_of_old: &[usize], new_stride: usize) {
        assert_eq!(self.stride, new_of_old.len(), "map/stride mismatch");
        assert!(new_stride <= self.stride);
        let old_stride = self.stride;
        let mut write = 0usize;
        for r in 0..self.rows {
            let start = r * old_stride;
            let row_write = write;
            for k in 0..old_stride {
                let mapped = new_of_old[self.data[start + k]];
                if mapped != usize::MAX {
                    self.data[write] = mapped;
                    write += 1;
                }
            }
            debug_assert_eq!(
                write - row_write,
                new_stride,
                "stored order was not a permutation of the old ground set"
            );
        }
        self.stride = new_stride;
        self.data.truncate(self.rows * new_stride);
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_basics() {
        let mut m = Mat::zeros(2, 3);
        m[(0, 1)] = 2.0;
        m[(1, 2)] = -1.0;
        assert_eq!(m.row(0), &[0.0, 2.0, 0.0]);
        let y = m.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, -1.0]);
    }

    #[test]
    fn eye_matvec_is_identity() {
        let m = Mat::eye(4);
        let x = [1.0, -2.0, 3.0, 0.5];
        assert_eq!(m.matvec(&x), x.to_vec());
    }

    #[test]
    fn corral_mat_push_remove_compact() {
        let mut m = CorralMat::new(3);
        assert!(m.is_empty());
        m.push(&[1.0, 2.0, 3.0]);
        m.push(&[4.0, 5.0, 6.0]);
        m.push(&[7.0, 8.0, 9.0]);
        m.push(&[10.0, 11.0, 12.0]);
        assert_eq!(m.len(), 4);
        assert_eq!(m.row(2), &[7.0, 8.0, 9.0]);
        m.remove(1); // order-preserving
        assert_eq!(m.len(), 3);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[7.0, 8.0, 9.0]);
        assert_eq!(m.row(2), &[10.0, 11.0, 12.0]);
        let rows: Vec<&[f64]> = m.iter().collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1], m.row(1));
        m.compact(&[0, 2]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        m.reset(2);
        assert_eq!(m.len(), 0);
        m.push(&[1.0, 2.0]);
        assert_eq!(m.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn corral_mat_row_mut_and_reshape() {
        let mut m = CorralMat::new(4);
        m.push(&[1.0, 2.0, 3.0, 4.0]);
        m.push(&[5.0, 6.0, 7.0, 8.0]);
        m.row_mut(1)[0] = -5.0;
        assert_eq!(m.row(1), &[-5.0, 6.0, 7.0, 8.0]);
        m.reshape_rows(2);
        assert_eq!(m.len(), 2);
        assert_eq!(m.stride(), 2);
        m.row_mut(0).copy_from_slice(&[9.0, 10.0]);
        m.row_mut(1).copy_from_slice(&[11.0, 12.0]);
        assert_eq!(m.row(0), &[9.0, 10.0]);
        assert_eq!(m.row(1), &[11.0, 12.0]);
    }

    #[test]
    fn index_mat_push_compact_contract() {
        let mut m = IndexMat::new(5);
        assert!(m.is_empty());
        m.push(&[4, 1, 0, 3, 2]);
        m.push(&[0, 1, 2, 3, 4]);
        m.push(&[2, 3, 4, 0, 1]);
        assert_eq!(m.len(), 3);
        assert_eq!(m.row(0), &[4, 1, 0, 3, 2]);
        m.compact(&[0, 2]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(1), &[2, 3, 4, 0, 1]);
        // Contract: drop old elements 1 and 3 (survivors 0→0, 2→1, 4→2).
        let map = [0, usize::MAX, 1, usize::MAX, 2];
        m.contract(&map, 3);
        assert_eq!(m.stride(), 3);
        assert_eq!(m.row(0), &[2, 0, 1]); // from [4,1,0,3,2]
        assert_eq!(m.row(1), &[1, 2, 0]); // from [2,3,4,0,1]
        m.reset(2);
        assert!(m.is_empty());
        m.push(&[1, 0]);
        assert_eq!(m.row(0), &[1, 0]);
    }

    #[test]
    fn symmetrize_works() {
        let mut m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        m.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }
}
