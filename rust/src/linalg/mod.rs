//! Small dense linear-algebra toolkit.
//!
//! The library needs exactly three things from linear algebra:
//!
//! 1. Cholesky factorization + triangular solves (min-norm-point affine
//!    minimization over the corral Gram matrix),
//! 2. *incrementally extended* Cholesky factors (GP log-determinants along
//!    nested prefix sets for the Gaussian mutual-information oracle, and
//!    rank-one corral updates in the optimized min-norm solver),
//! 3. basic vector operations used across solvers and screening.
//!
//! No external BLAS: the corral dimension is small (≤ a few hundred) and the
//! GP kernels are ≤ a few thousand, so straightforward cache-friendly loops
//! are adequate and keep the build fully offline.

pub mod cholesky;
pub mod vecops;

pub use cholesky::{Cholesky, IncrementalCholesky};
pub use vecops::*;

/// Dense row-major matrix, minimal by design.
#[derive(Clone, Debug)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows * cols` entries.
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix–vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = vecops::dot(self.row(i), x);
        }
        y
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Symmetrize in place: `A <- (A + A^T) / 2`. Requires square.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_basics() {
        let mut m = Mat::zeros(2, 3);
        m[(0, 1)] = 2.0;
        m[(1, 2)] = -1.0;
        assert_eq!(m.row(0), &[0.0, 2.0, 0.0]);
        let y = m.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, -1.0]);
    }

    #[test]
    fn eye_matvec_is_identity() {
        let m = Mat::eye(4);
        let x = [1.0, -2.0, 3.0, 0.5];
        assert_eq!(m.matvec(&x), x.to_vec());
    }

    #[test]
    fn symmetrize_works() {
        let mut m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        m.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }
}
