//! Concave-of-cardinality functions `F(A) = g(|A|) + m(A)`.
//!
//! For concave `g` with `g(0) = 0` these are submodular (they are the
//! canonical "symmetric" family); combined with a modular tilt they produce
//! SFM instances with tunable minimizer size — useful for ablations and for
//! property tests where the exact minimizer is analytically known.

use super::Submodular;

/// `F(A) = g(|A|) + m(A)` with `g` tabulated at `0..=p` and concave.
#[derive(Clone, Debug)]
pub struct ConcaveCardFn {
    g: Vec<f64>,
    m: Vec<f64>,
}

impl ConcaveCardFn {
    /// Build from a tabulated concave `g` (length `p+1`, `g[0] = 0`) and a
    /// modular vector `m` (length `p`). Panics if `g` is not concave.
    pub fn new(g: Vec<f64>, m: Vec<f64>) -> Self {
        assert_eq!(g.len(), m.len() + 1);
        assert!(g[0].abs() < 1e-12, "g(0) must be 0");
        for k in 1..g.len() - 1 {
            let left = g[k] - g[k - 1];
            let right = g[k + 1] - g[k];
            assert!(right <= left + 1e-12, "g not concave at {k}");
        }
        ConcaveCardFn { g, m }
    }

    /// `F(A) = scale * sqrt(|A|) + m(A)`.
    pub fn sqrt(p: usize, scale: f64, m: Vec<f64>) -> Self {
        let g = (0..=p).map(|k| scale * (k as f64).sqrt()).collect();
        Self::new(g, m)
    }

    /// Symmetric "soft cut": `F(A) = scale * min(|A|, p−|A|) + m(A)`.
    pub fn symmetric_min(p: usize, scale: f64, m: Vec<f64>) -> Self {
        let g = (0..=p)
            .map(|k| scale * (k.min(p - k) as f64))
            .collect();
        Self::new(g, m)
    }
}

impl Submodular for ConcaveCardFn {
    fn ground_size(&self) -> usize {
        self.m.len()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        let k = set.iter().filter(|&&b| b).count();
        let modular: f64 =
            set.iter().zip(&self.m).filter(|(&b, _)| b).map(|(_, &w)| w).sum();
        self.g[k] + modular
    }

    // Already allocation-free, so the default `prefix_gains_scratch`
    // (which forwards here) is the zero-allocation hot path too.
    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut k = base.iter().filter(|&&b| b).count();
        for (o, &j) in out.iter_mut().zip(order) {
            *o = self.g[k + 1] - self.g[k] + self.m[j];
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn sqrt_family_axioms() {
        let m: Vec<f64> = (0..9).map(|i| (i as f64) * 0.2 - 0.9).collect();
        let f = ConcaveCardFn::sqrt(9, 2.0, m);
        check_axioms(&f, 31, 1e-9);
        check_gains_match_eval(&f, 32, 1e-12);
    }

    #[test]
    fn symmetric_min_axioms() {
        let m: Vec<f64> = (0..8).map(|i| ((i * 7) % 5) as f64 * 0.3 - 0.6).collect();
        let f = ConcaveCardFn::symmetric_min(8, 1.5, m);
        check_axioms(&f, 33, 1e-9);
        check_gains_match_eval(&f, 34, 1e-12);
    }

    #[test]
    #[should_panic(expected = "not concave")]
    fn rejects_convex_g() {
        ConcaveCardFn::new(vec![0.0, 1.0, 3.0], vec![0.0, 0.0]);
    }

    #[test]
    fn known_minimizer_when_modular_dominates() {
        // Strongly negative modular weight on element 0 pulls it into A*.
        let mut m = vec![1.0; 6];
        m[0] = -10.0;
        let f = ConcaveCardFn::sqrt(6, 1.0, m);
        // F({0}) = 1 - 10 = -9 < 0 = F(∅); adding anything else costs +1+Δg.
        assert!(f.eval_ids(&[0]) < 0.0);
        assert!(f.eval_ids(&[0, 1]) > f.eval_ids(&[0]));
    }
}
