//! Modular (additive) set functions `F(A) = Σ_{j∈A} w_j`.
//!
//! Modular functions are both submodular and supermodular; they are the
//! building block for the paper's parameterized family SFM′
//! (`F(A) + Σ_{j∈A} ∇ψ_j(α)`) and for the unary terms of the experiment
//! objectives.

use super::{OracleScratch, Submodular};

/// `F(A) = w(A)`.
#[derive(Clone, Debug)]
pub struct ModularFn {
    w: Vec<f64>,
}

impl ModularFn {
    /// Build from per-element weights.
    pub fn new(w: Vec<f64>) -> Self {
        ModularFn { w }
    }

    /// The weight vector.
    pub fn weights(&self) -> &[f64] {
        &self.w
    }
}

impl Submodular for ModularFn {
    fn ground_size(&self) -> usize {
        self.w.len()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.w.len());
        set.iter().zip(&self.w).filter(|(&b, _)| b).map(|(_, &w)| w).sum()
    }

    fn prefix_gains_from(&self, _base: &[bool], order: &[usize], out: &mut [f64]) {
        for (o, &j) in out.iter_mut().zip(order) {
            *o = self.w[j];
        }
    }
}

/// The sum `F + m` of a submodular function and a modular function, sharing
/// the same ground set. Used to express SFM′ and the unary-augmented
/// experiment objectives without copying oracles.
pub struct PlusModular<F> {
    inner: F,
    m: Vec<f64>,
}

impl<F: Submodular> PlusModular<F> {
    /// `F(A) + m(A)`.
    pub fn new(inner: F, m: Vec<f64>) -> Self {
        assert_eq!(inner.ground_size(), m.len());
        PlusModular { inner, m }
    }

    /// The wrapped submodular part.
    pub fn inner(&self) -> &F {
        &self.inner
    }

    /// The modular weights.
    pub fn modular(&self) -> &[f64] {
        &self.m
    }
}

impl<F: Submodular> Submodular for PlusModular<F> {
    fn ground_size(&self) -> usize {
        self.inner.ground_size()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        let mut v = self.inner.eval(set);
        for (j, &b) in set.iter().enumerate() {
            if b {
                v += self.m[j];
            }
        }
        v
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        self.inner.prefix_gains_from(base, order, out);
        for (o, &j) in out.iter_mut().zip(order) {
            *o += self.m[j];
        }
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // The modular layer has no pass state of its own — thread the
        // scratch straight into the wrapped oracle so composed objectives
        // stay on the zero-allocation path.
        self.inner.prefix_gains_scratch(base, order, out, scratch);
        for (o, &j) in out.iter_mut().zip(order) {
            *o += self.m[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn modular_axioms() {
        let f = ModularFn::new(vec![0.3, -1.0, 2.0, 0.0, -0.7]);
        check_axioms(&f, 11, 1e-12);
        check_gains_match_eval(&f, 12, 1e-12);
    }

    #[test]
    fn plus_modular_matches_sum() {
        let f = ModularFn::new(vec![1.0, 2.0, 3.0]);
        let g = PlusModular::new(f, vec![-1.0, 0.5, 0.0]);
        assert_eq!(g.eval_ids(&[0]), 0.0);
        assert_eq!(g.eval_ids(&[0, 1]), 2.5);
        check_gains_match_eval(&g, 13, 1e-12);
    }
}
