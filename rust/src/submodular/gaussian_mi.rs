//! Gaussian-process mutual information — the paper's exact two-moons
//! objective (§4.1).
//!
//! `F(A) = I(f_A; f_{V∖A}) + m(A)` where `f ~ GP(0, K)` with a Gaussian
//! kernel `K_ij = exp(−α‖x_i−x_j‖²)` (+ observation noise σ² on the
//! diagonal for conditioning) and the modular label term
//! `m_j = −log η_j + log(1 − η_j)` from the semi-supervised labels.
//!
//! The mutual information between the restriction of a GP to `A` and its
//! complement is
//!
//! ```text
//! I(f_A; f_{V∖A}) = H(A) + H(V∖A) − H(V),   H(A) = ½ log det K_AA
//! ```
//!
//! (entropies up to the common `½|A| log 2πe` terms, which cancel in `I`
//! only partially — we keep them implicitly by folding noise into `K`;
//! symmetric-submodularity holds either way since entropy is submodular).
//!
//! **Greedy pass**: along an order, the prefix sets are nested, so `H(A_k)`
//! comes from one *extending* Cholesky; the complements `V∖A_k` are nested
//! along the *reversed* order, so `H(V∖A_k)` comes from a second extending
//! Cholesky run backwards. One greedy pass is therefore two O(p³/3)
//! factorizations — exactly the cost profile the paper's Matlab experiment
//! pays, which is why their `p = 1000` baseline takes 5400 s.

use super::{OracleScratch, Submodular};
use crate::linalg::{Cholesky, Mat};

/// GP mutual-information + modular labels.
#[derive(Clone, Debug)]
pub struct GaussianMiFn {
    p: usize,
    /// Row-major `p×p` kernel matrix including the noise diagonal.
    k: Vec<f64>,
    /// Modular term.
    m: Vec<f64>,
    /// Cached `H(V) = ½ log det K` (constant).
    h_full: f64,
}

impl GaussianMiFn {
    /// Build from a PSD kernel matrix (row-major, `p×p`), observation noise
    /// `sigma2 > 0` added to the diagonal, and a modular vector.
    pub fn new(p: usize, mut k: Vec<f64>, sigma2: f64, m: Vec<f64>) -> Self {
        assert_eq!(k.len(), p * p);
        assert_eq!(m.len(), p);
        assert!(sigma2 > 0.0, "need positive noise for conditioning");
        for i in 0..p {
            k[i * p + i] += sigma2;
        }
        let mat = Mat { rows: p, cols: p, data: k.clone() };
        let ch = Cholesky::factor(&mat, 1e-10).expect("kernel matrix not PD");
        let h_full = 0.5 * ch.logdet();
        GaussianMiFn { p, k, m, h_full }
    }

    /// Build from points with a Gaussian kernel `exp(−α‖xi−xj‖²)`.
    pub fn from_points(points: &[[f64; 2]], alpha: f64, sigma2: f64, m: Vec<f64>) -> Self {
        let p = points.len();
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in 0..p {
                let dx = points[i][0] - points[j][0];
                let dy = points[i][1] - points[j][1];
                k[i * p + j] = (-alpha * (dx * dx + dy * dy)).exp();
            }
        }
        Self::new(p, k, sigma2, m)
    }

    #[inline]
    fn kk(&self, i: usize, j: usize) -> f64 {
        self.k[i * self.p + j]
    }

    /// `H(A) = ½ log det K_AA` for ids.
    fn entropy_ids(&self, ids: &[usize]) -> f64 {
        if ids.is_empty() {
            return 0.0;
        }
        let n = ids.len();
        let sub = Mat::from_fn(n, n, |a, b| self.kk(ids[a], ids[b]));
        let ch = Cholesky::factor(&sub, 1e-10).expect("principal minor not PD");
        0.5 * ch.logdet()
    }
}

impl Submodular for GaussianMiFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let a_ids: Vec<usize> = (0..self.p).filter(|&i| set[i]).collect();
        let b_ids: Vec<usize> = (0..self.p).filter(|&i| !set[i]).collect();
        let modular: f64 = a_ids.iter().map(|&i| self.m[i]).sum();
        self.entropy_ids(&a_ids) + self.entropy_ids(&b_ids) - self.h_full + modular
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        let n = order.len();
        if n == 0 {
            return;
        }
        // Scratch layout: `ids` holds base ids then (reused) rest ids,
        // `ids2` the incremental-factor member list, `acc`/`aux` the two
        // entropy ladders, `aux2` the cross row, `mem_bool` the in-order
        // mask, and `chol` the extending factor (the two passes run
        // sequentially; reset between them, capacity retained).
        let OracleScratch {
            mem_bool: in_order,
            ids,
            ids2: members,
            acc: h_fwd,
            aux: h_bwd,
            aux2: cross,
            chol,
            ..
        } = scratch;

        // Forward pass: H(base ∪ prefix_k) for k = 0..=n via one extending
        // Cholesky seeded with the base set.
        ids.clear();
        ids.extend((0..self.p).filter(|&i| base[i]));
        h_fwd.clear();
        h_fwd.resize(n + 1, 0.0); // h_fwd[k] = H(base ∪ prefix_k)
        {
            chol.reset();
            members.clear();
            let mut logdet = 0.0;
            for &i in ids.iter() {
                cross.clear();
                cross.extend(members.iter().map(|&j| self.kk(i, j)));
                let ld = chol.push(cross, self.kk(i, i), 1e-10).expect("PD");
                logdet += 2.0 * ld.ln();
                members.push(i);
            }
            h_fwd[0] = 0.5 * logdet;
            for (k, &i) in order.iter().enumerate() {
                cross.clear();
                cross.extend(members.iter().map(|&j| self.kk(i, j)));
                let ld = chol.push(cross, self.kk(i, i), 1e-10).expect("PD");
                logdet += 2.0 * ld.ln();
                members.push(i);
                h_fwd[k + 1] = 0.5 * logdet;
            }
        }

        // Backward pass: the complements C_k = V ∖ (base ∪ prefix_k) are
        // nested decreasing; equivalently C_k = rest ∪ suffix_k where
        // rest = V ∖ (base ∪ order). Build from rest, then append order
        // reversed: after pushing t elements we have C_{n−t}.
        in_order.clear();
        in_order.resize(self.p, false);
        for &i in order {
            in_order[i] = true;
        }
        ids.clear();
        ids.extend((0..self.p).filter(|&i| !base[i] && !in_order[i]));
        h_bwd.clear();
        h_bwd.resize(n + 1, 0.0); // h_bwd[k] = H(V ∖ (base ∪ prefix_k))
        {
            chol.reset();
            members.clear();
            let mut logdet = 0.0;
            for &i in ids.iter() {
                cross.clear();
                cross.extend(members.iter().map(|&j| self.kk(i, j)));
                let ld = chol.push(cross, self.kk(i, i), 1e-10).expect("PD");
                logdet += 2.0 * ld.ln();
                members.push(i);
            }
            h_bwd[n] = 0.5 * logdet;
            for (t, &i) in order.iter().rev().enumerate() {
                cross.clear();
                cross.extend(members.iter().map(|&j| self.kk(i, j)));
                let ld = chol.push(cross, self.kk(i, i), 1e-10).expect("PD");
                logdet += 2.0 * ld.ln();
                members.push(i);
                h_bwd[n - 1 - t] = 0.5 * logdet;
            }
        }

        for k in 0..n {
            let j = order[k];
            out[k] =
                (h_fwd[k + 1] - h_fwd[k]) + (h_bwd[k + 1] - h_bwd[k]) + self.m[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    fn random_mi(p: usize, seed: u64) -> GaussianMiFn {
        let mut rng = Pcg64::seeded(seed);
        let points: Vec<[f64; 2]> =
            (0..p).map(|_| [rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)]).collect();
        let m = rng.uniform_vec(p, -0.5, 0.5);
        GaussianMiFn::from_points(&points, 1.5, 0.1, m)
    }

    #[test]
    fn axioms_and_gains() {
        let f = random_mi(9, 61);
        check_axioms(&f, 62, 1e-7);
        check_gains_match_eval(&f, 63, 1e-7);
    }

    #[test]
    fn normalized_and_symmetric_without_modular() {
        let mut rng = Pcg64::seeded(64);
        let points: Vec<[f64; 2]> =
            (0..8).map(|_| [rng.normal(), rng.normal()]).collect();
        let f = GaussianMiFn::from_points(&points, 1.0, 0.2, vec![0.0; 8]);
        assert!(f.eval_ids(&[]).abs() < 1e-9);
        assert!(f.eval_full().abs() < 1e-9);
        // MI is symmetric: F(A) = F(V∖A).
        for _ in 0..10 {
            let set: Vec<bool> = (0..8).map(|_| rng.bernoulli(0.5)).collect();
            let comp: Vec<bool> = set.iter().map(|&b| !b).collect();
            assert!((f.eval(&set) - f.eval(&comp)).abs() < 1e-8);
        }
    }

    #[test]
    fn mutual_information_nonnegative() {
        let f = random_mi(10, 65);
        let mut rng = Pcg64::seeded(66);
        for _ in 0..20 {
            let set: Vec<bool> = (0..10).map(|_| rng.bernoulli(0.5)).collect();
            // Strip modular part: evaluate with m and subtract.
            let m_sum: f64 = (0..10).filter(|&i| set[i]).map(|i| f.m[i]).sum();
            assert!(f.eval(&set) - m_sum > -1e-8, "MI must be ≥ 0");
        }
    }
}
