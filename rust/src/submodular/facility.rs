//! Facility-location-style functions: soft coverage via maxima.
//!
//! `F(A) = Σ_u w_u · max_{j∈A} s_{uj} − c(A)` (with `max_∅ = 0`): each
//! client `u` is served at the quality of the best open facility in `A`,
//! facilities cost `c_j`. The service term is monotone submodular (max of
//! nonnegative scores), so `F` is submodular; minimizing `−F`… here SFM
//! *minimizes* `F` directly, so negative costs model subsidies and the
//! minimizer balances service value against cost. A standard oracle
//! family with structure quite unlike cuts (per-client maxima), which is
//! exactly why the screening test battery includes it.

use super::{OracleScratch, Submodular};
use crate::linalg::vecops::{max_update_col4, relu_mac_col4};

/// Weighted facility-location value minus modular facility costs.
#[derive(Clone, Debug)]
pub struct FacilityLocationFn {
    /// `scores[u * p + j] = s_{uj} ≥ 0`, row-major clients × facilities.
    scores: Vec<f64>,
    /// Client weights `w_u ≥ 0`.
    client_w: Vec<f64>,
    /// Facility costs (subtracted; sign free).
    cost: Vec<f64>,
    /// Number of facilities `p`.
    p: usize,
}

impl FacilityLocationFn {
    /// Build from a dense score matrix (`clients × facilities`).
    pub fn new(clients: usize, p: usize, scores: Vec<f64>, client_w: Vec<f64>, cost: Vec<f64>) -> Self {
        assert_eq!(scores.len(), clients * p);
        assert_eq!(client_w.len(), clients);
        assert_eq!(cost.len(), p);
        assert!(scores.iter().all(|&s| s >= 0.0), "scores must be ≥ 0");
        assert!(client_w.iter().all(|&w| w >= 0.0), "client weights must be ≥ 0");
        FacilityLocationFn { scores, client_w, cost, p }
    }

    /// Random instance: facilities and clients as 2-D points, scores =
    /// Gaussian similarity, costs uniform.
    pub fn random(
        clients: usize,
        p: usize,
        rng: &mut crate::rng::Pcg64,
    ) -> Self {
        let fac: Vec<[f64; 2]> =
            (0..p).map(|_| [rng.uniform(0.0, 1.0), rng.uniform(0.0, 1.0)]).collect();
        let mut scores = Vec::with_capacity(clients * p);
        for _ in 0..clients {
            let c = [rng.uniform(0.0, 1.0), rng.uniform(0.0, 1.0)];
            for fj in &fac {
                let d2 = (c[0] - fj[0]).powi(2) + (c[1] - fj[1]).powi(2);
                scores.push((-4.0 * d2).exp());
            }
        }
        let client_w = rng.uniform_vec(clients, 0.2, 1.0);
        let cost = rng.uniform_vec(p, 0.0, 1.5);
        FacilityLocationFn::new(clients, p, scores, client_w, cost)
    }

    #[inline]
    fn num_clients(&self) -> usize {
        self.client_w.len()
    }
}

impl Submodular for FacilityLocationFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let mut v = 0.0;
        for u in 0..self.num_clients() {
            let row = &self.scores[u * self.p..(u + 1) * self.p];
            let mut best = 0.0f64;
            for (j, &inside) in set.iter().enumerate() {
                if inside && row[j] > best {
                    best = row[j];
                }
            }
            v += self.client_w[u] * best;
        }
        for (j, &inside) in set.iter().enumerate() {
            if inside {
                v -= self.cost[j];
            }
        }
        v
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // cur[u] = current best score for client u; adding facility j
        // contributes Σ_u w_u · max(0, s_uj − cur[u]) − c_j. `cur` is
        // client-indexed and rebuilt from `base` on entry. Both walks
        // over the facility column are branchless 4-lane kernels
        // (`vecops::{max_update_col4, relu_mac_col4}`) — scores and
        // weights are nonnegative, so `max` reproduces the branchy
        // update exactly.
        let clients = self.num_clients();
        let cur = &mut scratch.aux;
        cur.clear();
        cur.resize(clients, 0.0);
        for (j, &inb) in base.iter().enumerate() {
            if inb {
                max_update_col4(cur, &self.scores, j, self.p);
            }
        }
        for (o, &j) in out.iter_mut().zip(order) {
            *o = relu_mac_col4(cur, &self.client_w, &self.scores, j, self.p)
                - self.cost[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::rng::Pcg64;
    use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions};
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn axioms_and_gains() {
        let mut rng = Pcg64::seeded(606);
        let f = FacilityLocationFn::random(20, 9, &mut rng);
        check_axioms(&f, 607, 1e-9);
        check_gains_match_eval(&f, 608, 1e-12);
    }

    #[test]
    fn simple_instance_values() {
        // One client, two facilities.
        let f = FacilityLocationFn::new(
            1,
            2,
            vec![0.8, 0.5],
            vec![1.0],
            vec![0.1, 0.2],
        );
        assert_eq!(f.eval_ids(&[]), 0.0);
        assert!((f.eval_ids(&[0]) - 0.7).abs() < 1e-12);
        assert!((f.eval_ids(&[1]) - 0.3).abs() < 1e-12);
        // Both: max(0.8, 0.5) − 0.3 = 0.5.
        assert!((f.eval_full() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn iaes_is_safe_on_facility_location() {
        let mut rng = Pcg64::seeded(609);
        for _ in 0..4 {
            let f = FacilityLocationFn::random(15, 8, &mut rng);
            let brute = brute_force_sfm(&f, 1e-9);
            let report =
                solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
            assert!(
                (report.minimum - brute.minimum).abs() < 1e-6,
                "{} vs {}",
                report.minimum,
                brute.minimum
            );
        }
    }
}
