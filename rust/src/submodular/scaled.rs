//! The Lemma-1 ground-set reduction `F̂(C) = F(Ê ∪ C) − F(Ê)`.
//!
//! After IAES identifies active elements `Ê` (fixed *in* the minimizer) and
//! inactive elements `Ĝ` (fixed *out*), the residual problem is SFM over
//! `V̂ = V ∖ (Ê ∪ Ĝ)` with the contracted-and-restricted function `F̂`.
//! Lemma 1 proves `F̂` is submodular, `F̂(∅) = 0`, and
//! `A* = Ê ∪ argmin F̂`.
//!
//! [`ScaledFn`] keeps the *original* oracle plus a flat id mapping, so IAES
//! re-scaling at every trigger never builds nested wrappers — there is one
//! translation layer no matter how many times the problem shrank.

use super::{OracleScratch, Submodular};
use crate::lovasz::ContractionMap;

/// `F̂` over the reduced ground set `V̂`, referencing the original oracle.
pub struct ScaledFn<'a> {
    inner: &'a dyn Submodular,
    /// Membership of Ê in the original ground set.
    base: Vec<bool>,
    /// `kept[k]` = original id of reduced element `k` (sorted ascending).
    kept: Vec<usize>,
    /// `F(Ê)` cached.
    f_base: f64,
}

impl<'a> ScaledFn<'a> {
    /// Build the reduction. `active` and `kept` are original ids; `kept`
    /// must be disjoint from `active` (and implicitly from the discarded
    /// inactive set, which is simply "everything else").
    pub fn new(inner: &'a dyn Submodular, active: &[usize], kept: Vec<usize>) -> Self {
        let p = inner.ground_size();
        let mut base = vec![false; p];
        for &i in active {
            assert!(i < p);
            assert!(!base[i], "duplicate active id {i}");
            base[i] = true;
        }
        for &k in &kept {
            assert!(k < p && !base[k], "kept id {k} collides with active set");
        }
        let f_base = inner.eval(&base);
        ScaledFn { inner, base, kept, f_base }
    }

    /// Re-target the reduction in place: same inner oracle, new
    /// active/kept split. Reuses the membership and id buffers, so IAES
    /// warm restarts never rebuild the translation layer from scratch.
    /// Same contract as [`ScaledFn::new`]: `kept` must be disjoint from
    /// `active`.
    pub fn set_reduction(&mut self, active: &[usize], kept: &[usize]) {
        let p = self.inner.ground_size();
        self.base.clear();
        self.base.resize(p, false);
        for &i in active {
            assert!(i < p);
            assert!(!self.base[i], "duplicate active id {i}");
            self.base[i] = true;
        }
        for &k in kept {
            assert!(k < p && !self.base[k], "kept id {k} collides with active set");
        }
        self.kept.clear();
        self.kept.extend_from_slice(kept);
        self.f_base = self.inner.eval(&self.base);
    }

    /// Incremental re-targeting for an IAES contraction: every id in
    /// `new_active` moves from the kept set into the base `Ê`, `new_kept`
    /// (sorted, a subsequence of the current kept ids) becomes the new
    /// reduced ground set, and everything else that disappeared from
    /// `kept` is implicitly inactive. Unlike [`set_reduction`], the base
    /// membership is updated by flipping only the newly certified bits —
    /// O(p̂) instead of O(p) — and the old→new survivor map is written
    /// into `map_out`, which is what lets the solver *project* its state
    /// through the contraction ([`ProxSolver::reset_mapped`]) instead of
    /// rebuilding cold.
    ///
    /// [`set_reduction`]: ScaledFn::set_reduction
    /// [`ProxSolver::reset_mapped`]: crate::solvers::ProxSolver::reset_mapped
    pub fn contract(
        &mut self,
        new_active: &[usize],
        new_kept: &[usize],
        map_out: &mut ContractionMap,
    ) {
        map_out.rebuild(&self.kept, new_kept);
        for &a in new_active {
            assert!(a < self.base.len() && !self.base[a], "bad new-active id {a}");
            let old_idx = self
                .kept
                .binary_search(&a)
                .expect("new-active id was not in the kept set");
            map_out.mark_active(old_idx);
            self.base[a] = true;
        }
        self.kept.clear();
        self.kept.extend_from_slice(new_kept);
        self.f_base = self.inner.eval(&self.base);
    }

    /// Reduced ground-set ids mapped back to original ids.
    pub fn kept_ids(&self) -> &[usize] {
        &self.kept
    }

    /// `F(Ê)` — the constant subtracted by the reduction.
    pub fn base_value(&self) -> f64 {
        self.f_base
    }

    /// Translate a reduced-id set into original ids (plus the base set).
    pub fn to_original_ids(&self, reduced: &[usize]) -> Vec<usize> {
        let mut ids: Vec<usize> =
            self.base.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect();
        ids.extend(reduced.iter().map(|&k| self.kept[k]));
        ids.sort_unstable();
        ids
    }
}

impl Submodular for ScaledFn<'_> {
    fn ground_size(&self) -> usize {
        self.kept.len()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.kept.len());
        let mut full = self.base.clone();
        for (k, &b) in set.iter().enumerate() {
            if b {
                full[self.kept[k]] = true;
            }
        }
        self.inner.eval(&full) - self.f_base
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // Translate: reduced base ∪ Ê is the original base; reduced order
        // maps through `kept`. The −F(Ê) constant cancels in differences.
        // The translation buffers and the inner oracle's pass state all
        // live in `scratch` (the inner oracle gets the nested scratch —
        // with the parallel-oracle pool handle re-propagated, so pooled
        // kernels keep working under any number of reductions), so the
        // one translation layer stays allocation-free no matter how many
        // times the problem shrank.
        assert_eq!(base.len(), self.kept.len());
        let OracleScratch { mem_bool: full_base, ids: mapped, inner, pool, .. } = scratch;
        full_base.clear();
        full_base.extend_from_slice(&self.base);
        for (k, &b) in base.iter().enumerate() {
            if b {
                full_base[self.kept[k]] = true;
            }
        }
        mapped.clear();
        mapped.extend(order.iter().map(|&k| self.kept[k]));
        let nested = inner.get_or_insert_with(Default::default);
        nested.pool = pool.clone();
        self.inner.prefix_gains_scratch(full_base, mapped, out, nested);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn reduction_matches_definition() {
        let f = IwataFn::new(12);
        let active = vec![1, 5];
        let kept = vec![0, 2, 3, 7, 9];
        let scaled = ScaledFn::new(&f, &active, kept.clone());
        assert!(scaled.eval_ids(&[]).abs() < 1e-12, "F̂(∅) = 0");
        // F̂({0,3}) = F({1,5} ∪ {kept[0],kept[3]}) − F({1,5})
        let lhs = scaled.eval_ids(&[0, 3]);
        let rhs = f.eval_ids(&[0, 1, 5, 7]) - f.eval_ids(&[1, 5]);
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn scaled_stays_submodular() {
        let mut rng = Pcg64::seeded(81);
        let p = 10;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        let f = KernelCutFn::new(p, k, unary);
        let scaled = ScaledFn::new(&f, &[2, 8], vec![0, 1, 4, 5, 9]);
        check_axioms(&scaled, 82, 1e-9);
        check_gains_match_eval(&scaled, 83, 1e-9);
    }

    #[test]
    fn set_reduction_matches_fresh_construction() {
        let f = IwataFn::new(12);
        let mut scaled = ScaledFn::new(&f, &[1, 5], vec![0, 2, 3, 7, 9]);
        // Re-target to a different split and compare against a fresh build.
        scaled.set_reduction(&[0, 4], &[2, 5, 6, 11]);
        let fresh = ScaledFn::new(&f, &[0, 4], vec![2, 5, 6, 11]);
        assert_eq!(scaled.ground_size(), fresh.ground_size());
        assert_eq!(scaled.kept_ids(), fresh.kept_ids());
        assert_eq!(scaled.base_value(), fresh.base_value());
        for ids in [vec![], vec![0], vec![1, 3], vec![0, 1, 2, 3]] {
            assert_eq!(scaled.eval_ids(&ids), fresh.eval_ids(&ids));
        }
    }

    #[test]
    fn contract_matches_set_reduction_and_fills_map() {
        let f = IwataFn::new(12);
        let mut scaled = ScaledFn::new(&f, &[1], vec![0, 2, 3, 7, 9, 10]);
        let mut map = ContractionMap::new();
        // Certify reduced element 1 (orig 2) active, drop orig 7 and 10
        // as inactive; survivors are orig {0, 3, 9}.
        scaled.contract(&[2], &[0, 3, 9], &mut map);
        let fresh = ScaledFn::new(&f, &[1, 2], vec![0, 3, 9]);
        assert_eq!(scaled.ground_size(), fresh.ground_size());
        assert_eq!(scaled.kept_ids(), fresh.kept_ids());
        assert_eq!(scaled.base_value(), fresh.base_value());
        for ids in [vec![], vec![0], vec![1, 2], vec![0, 1, 2]] {
            assert_eq!(scaled.eval_ids(&ids), fresh.eval_ids(&ids));
        }
        // Map: old reduced {0:0, 2:3, 3:7, 9:…} — old kept was
        // [0,2,3,7,9,10], survivors [0,3,9] → 0→0, 3→1, 9→2.
        assert_eq!(map.old_len(), 6);
        assert_eq!(map.new_len(), 3);
        assert_eq!(map.new_index(0), Some(0)); // orig 0
        assert_eq!(map.new_index(1), None); // orig 2: activated
        assert_eq!(map.new_index(2), Some(1)); // orig 3
        assert_eq!(map.new_index(3), None); // orig 7: inactive
        assert_eq!(map.new_index(4), Some(2)); // orig 9
        assert_eq!(map.new_index(5), None); // orig 10: inactive
    }

    #[test]
    fn to_original_ids_merges_base() {
        let f = IwataFn::new(8);
        let scaled = ScaledFn::new(&f, &[6, 2], vec![0, 3, 5]);
        assert_eq!(scaled.to_original_ids(&[1, 2]), vec![2, 3, 5, 6]);
    }

    #[test]
    fn minimizer_recovery_lemma1() {
        // Brute-force check of Lemma 1(iii) on a small instance.
        let f = IwataFn::new(9);
        // Compute the true minimum of F.
        let p = 9;
        let mut best = f64::INFINITY;
        for mask in 0u32..(1 << p) {
            let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
            best = best.min(f.eval(&set));
        }
        // Take Ê = elements in EVERY minimizer, Ĝ = in none (computed brute
        // force), reduce, re-minimize, recover.
        let mut always = vec![true; p];
        let mut never = vec![true; p];
        for mask in 0u32..(1 << p) {
            let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
            if (f.eval(&set) - best).abs() < 1e-9 {
                for i in 0..p {
                    if !set[i] {
                        always[i] = false;
                    } else {
                        never[i] = false;
                    }
                }
            }
        }
        let active: Vec<usize> = (0..p).filter(|&i| always[i]).collect();
        let kept: Vec<usize> = (0..p).filter(|&i| !always[i] && !never[i]).collect();
        let scaled = ScaledFn::new(&f, &active, kept.clone());
        let ph = scaled.ground_size();
        let mut best_red = f64::INFINITY;
        let mut best_set = Vec::new();
        for mask in 0u32..(1 << ph) {
            let ids: Vec<usize> = (0..ph).filter(|i| mask >> i & 1 == 1).collect();
            let v = scaled.eval_ids(&ids);
            if v < best_red {
                best_red = v;
                best_set = ids;
            }
        }
        let recovered = scaled.to_original_ids(&best_set);
        assert!((f.eval_ids(&recovered) - best).abs() < 1e-9);
    }
}
