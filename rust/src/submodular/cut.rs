//! Sparse graph-cut functions — the image-segmentation objective (§4.2).
//!
//! `F(A) = u(A) + Σ_{i∈A, j∈V∖A} d(i, j)` with symmetric nonnegative
//! pairwise weights `d` on a sparse graph (8-neighbor pixel grid in the
//! paper) and a unary potential `u` from a GMM foreground/background model.
//!
//! Storage is CSR (each undirected edge appears in both adjacency lists);
//! a greedy pass costs O(p + E) — the whole point of using sparse cuts for
//! large images.

use super::{OracleScratch, Submodular};
use crate::linalg::vecops::dot_gather4;
use crate::runtime::pool::{DisjointSlice, WorkerPool};

/// Adjacency-walk chunk length. A vertex's membership-weighted neighbor
/// sum is always reduced over `⌈deg / ADJ_CHUNK⌉` fixed chunks — one
/// [`dot_gather4`] partial per chunk, partials folded in chunk order —
/// so the reduction tree depends only on the degree, never on the
/// thread count (a single-chunk walk IS the plain `dot_gather4`).
const ADJ_CHUNK: usize = 1024;

/// Pooled walks engage at this degree: below it a dispatch costs more
/// than the row. The gate only moves the same fixed-chunk arithmetic
/// between threads, so it is unobservable in the results.
const ADJ_POOL_MIN: usize = 4096;

/// The canonical chunked adjacency reduction — the **single source of
/// truth** for the determinism contract: `dot_gather4` partials over the
/// fixed `ADJ_CHUNK` grid, folded left-to-right from the first partial.
/// With a pool (and a row long enough to pay for a dispatch) the
/// partials are computed across the workers — each chunk slot owned by
/// exactly one worker — otherwise sequentially; the grid and the fold
/// are identical either way, so both arms are bit-equal by
/// construction. `partials` is caller-owned scratch (resized here).
fn chunked_adjacency_sum(
    ws: &[f64],
    nbrs: &[u32],
    inside: &[f64],
    partials: &mut Vec<f64>,
    pool: Option<&WorkerPool>,
) -> f64 {
    debug_assert!(!ws.is_empty());
    let nchunks = ws.len().div_ceil(ADJ_CHUNK);
    partials.clear();
    partials.resize(nchunks, 0.0);
    match pool {
        Some(pool) if ws.len() >= ADJ_POOL_MIN => {
            let parts = DisjointSlice::new(partials);
            pool.run_chunks(ws.len(), ADJ_CHUNK, &|r: std::ops::Range<usize>| {
                let c = r.start / ADJ_CHUNK;
                // SAFETY: each chunk index is visited exactly once.
                let slot = unsafe { parts.slice_mut(c..c + 1) };
                slot[0] = dot_gather4(&ws[r.clone()], &nbrs[r.clone()], inside);
            });
        }
        _ => {
            for (c, p_out) in partials.iter_mut().enumerate() {
                let lo = c * ADJ_CHUNK;
                let hi = ws.len().min(lo + ADJ_CHUNK);
                *p_out = dot_gather4(&ws[lo..hi], &nbrs[lo..hi], inside);
            }
        }
    }
    fold_partials(partials)
}

/// Fold chunk partials in fixed chunk order, seeded from the first
/// partial (so a one-chunk walk is bitwise the plain `dot_gather4`).
fn fold_partials(partials: &[f64]) -> f64 {
    let mut s = partials[0];
    for &x in &partials[1..] {
        s += x;
    }
    s
}

/// A weighted undirected graph cut plus unary terms.
#[derive(Clone, Debug)]
pub struct CutFn {
    /// Unary potentials, one per vertex.
    unary: Vec<f64>,
    /// CSR offsets, length `p + 1`.
    offsets: Vec<usize>,
    /// Neighbor ids.
    neighbors: Vec<u32>,
    /// Edge weights aligned with `neighbors`.
    weights: Vec<f64>,
    /// Σ_j w_ij per vertex (cached: the "degree").
    degree: Vec<f64>,
}

impl CutFn {
    /// Build from an edge list of `(i, j, w)` with `w ≥ 0` and a unary
    /// vector. Each undirected edge is listed once.
    pub fn from_edges(p: usize, edges: &[(usize, usize, f64)], unary: Vec<f64>) -> Self {
        assert_eq!(unary.len(), p);
        let mut deg_count = vec![0usize; p];
        for &(i, j, w) in edges {
            assert!(i < p && j < p && i != j, "bad edge ({i},{j})");
            assert!(w >= 0.0, "negative cut weight");
            deg_count[i] += 1;
            deg_count[j] += 1;
        }
        let mut offsets = vec![0usize; p + 1];
        for i in 0..p {
            offsets[i + 1] = offsets[i] + deg_count[i];
        }
        let total = offsets[p];
        let mut neighbors = vec![0u32; total];
        let mut weights = vec![0.0; total];
        let mut cursor = offsets.clone();
        for &(i, j, w) in edges {
            neighbors[cursor[i]] = j as u32;
            weights[cursor[i]] = w;
            cursor[i] += 1;
            neighbors[cursor[j]] = i as u32;
            weights[cursor[j]] = w;
            cursor[j] += 1;
        }
        let mut degree = vec![0.0; p];
        for i in 0..p {
            degree[i] = weights[offsets[i]..offsets[i + 1]].iter().sum();
        }
        CutFn { unary, offsets, neighbors, weights, degree }
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Unary potentials.
    pub fn unary(&self) -> &[f64] {
        &self.unary
    }

    #[inline]
    fn adj(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.offsets[i];
        let hi = self.offsets[i + 1];
        (&self.neighbors[lo..hi], &self.weights[lo..hi])
    }
}

impl Submodular for CutFn {
    fn ground_size(&self) -> usize {
        self.unary.len()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.unary.len());
        let mut v = 0.0;
        for (i, &inside) in set.iter().enumerate() {
            if inside {
                v += self.unary[i];
                let (nbrs, ws) = self.adj(i);
                for (&j, &w) in nbrs.iter().zip(ws) {
                    if !set[j as usize] {
                        v += w;
                    }
                }
            }
        }
        v
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // Membership evolves as we walk the order; marginal gain of v:
        //   u_v + Σ_{j∉A} w_vj − Σ_{j∈A} w_vj = u_v + deg_v − 2 Σ_{j∈A} w_vj.
        // Membership is stored as f64 0/1 so the adjacency walk is a
        // branchless multiply-accumulate (`vecops::dot_gather4`;
        // membership is effectively random mid-solve, so an `if`
        // mispredicts half the time). The membership buffer is rebuilt
        // from `base` on entry, so the scratch carries no state between
        // passes.
        //
        // The walk is reduced over the fixed ADJ_CHUNK grid whenever the
        // row spans more than one chunk; with a pool installed, rows of
        // degree ≥ ADJ_POOL_MIN compute their chunk partials across the
        // workers (each partial owned by exactly one chunk) and fold
        // them in the identical chunk order — bitwise equal to the
        // sequential walk at every thread count.
        let OracleScratch { mem_f64: inside, aux2: partials, pool, .. } = scratch;
        let pool = pool.clone();
        inside.clear();
        inside.extend(base.iter().map(|&b| if b { 1.0 } else { 0.0 }));
        for (o, &v) in out.iter_mut().zip(order) {
            debug_assert_eq!(inside[v], 0.0);
            let (nbrs, ws) = self.adj(v);
            let in_sum = if ws.is_empty() {
                0.0
            } else if ws.len() <= ADJ_CHUNK {
                dot_gather4(ws, nbrs, inside)
            } else {
                chunked_adjacency_sum(ws, nbrs, inside, partials, pool.as_deref())
            };
            *o = self.unary[v] + self.degree[v] - 2.0 * in_sum;
            inside[v] = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    fn random_graph(p: usize, m: usize, seed: u64) -> CutFn {
        let mut rng = Pcg64::seeded(seed);
        let mut edges = Vec::new();
        let mut seen = std::collections::HashSet::new();
        while edges.len() < m {
            let i = rng.below(p);
            let j = rng.below(p);
            if i == j {
                continue;
            }
            let key = (i.min(j), i.max(j));
            if seen.insert(key) {
                edges.push((key.0, key.1, rng.uniform(0.0, 2.0)));
            }
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        CutFn::from_edges(p, &edges, unary)
    }

    #[test]
    fn axioms_and_gains() {
        let f = random_graph(12, 25, 41);
        check_axioms(&f, 42, 1e-9);
        check_gains_match_eval(&f, 43, 1e-12);
    }

    #[test]
    fn triangle_cut_values() {
        // Triangle with unit weights, zero unaries.
        let f = CutFn::from_edges(
            3,
            &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)],
            vec![0.0; 3],
        );
        assert_eq!(f.eval_ids(&[]), 0.0);
        assert_eq!(f.eval_ids(&[0]), 2.0);
        assert_eq!(f.eval_ids(&[0, 1]), 2.0);
        assert_eq!(f.eval_full(), 0.0);
    }

    #[test]
    fn unary_shifts_cut() {
        let f = CutFn::from_edges(2, &[(0, 1, 3.0)], vec![-5.0, 1.0]);
        assert_eq!(f.eval_ids(&[0]), -2.0); // -5 + 3
        assert_eq!(f.eval_ids(&[1]), 4.0); // 1 + 3
        assert_eq!(f.eval_full(), -4.0); // -5 + 1
    }

    #[test]
    fn chunked_and_pooled_hub_walks_are_bit_identical() {
        // A hub vertex of degree ≥ ADJ_POOL_MIN forces both the fixed-
        // chunk reduction (always, degree > ADJ_CHUNK) and the pooled
        // partial computation (pool installed). All three paths — plain
        // sequential scratch, pooled at 2 lanes, pooled at 4 lanes —
        // must agree bit for bit, and the hub gain must match the
        // eval-based definition.
        use crate::runtime::pool::WorkerPool;
        use crate::submodular::OracleScratch;
        use std::sync::Arc;
        let p = ADJ_POOL_MIN + 350; // hub degree spans 4 full chunks + tail
        let mut rng = Pcg64::seeded(4646);
        let mut edges = Vec::with_capacity(p - 1);
        for j in 1..p {
            edges.push((0usize, j, rng.uniform(0.0, 1.0)));
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        let f = CutFn::from_edges(p, &edges, unary);
        // Order: a random slice of leaves first (so membership is mixed),
        // then the hub, then more leaves.
        let mut order: Vec<usize> = (1..p).collect();
        rng.shuffle(&mut order);
        order.insert(p / 2, 0);
        let base = vec![false; p];
        let mut seq = OracleScratch::new();
        let mut expect = vec![0.0; p];
        f.prefix_gains_scratch(&base, &order, &mut expect, &mut seq);
        for t in [2usize, 4] {
            let mut pooled = OracleScratch::new();
            pooled.set_pool(Some(Arc::new(WorkerPool::new(t - 1))));
            let mut got = vec![f64::NAN; p];
            f.prefix_gains_scratch(&base, &order, &mut got, &mut pooled);
            for k in 0..p {
                assert_eq!(got[k].to_bits(), expect[k].to_bits(), "t={t}, gain {k}");
            }
        }
        // The hub's gain (at position p/2) against the defining marginal.
        let mut set = vec![false; p];
        for &v in &order[..p / 2] {
            set[v] = true;
        }
        let before = f.eval(&set);
        set[0] = true;
        let after = f.eval(&set);
        assert!(
            (expect[p / 2] - (after - before)).abs() < 1e-9 * (1.0 + (after - before).abs()),
            "hub gain {} vs eval marginal {}",
            expect[p / 2],
            after - before
        );
    }

    #[test]
    fn symmetric_when_no_unary() {
        let f = random_graph(10, 20, 44);
        let zero_unary = CutFn {
            unary: vec![0.0; 10],
            offsets: f.offsets.clone(),
            neighbors: f.neighbors.clone(),
            weights: f.weights.clone(),
            degree: f.degree.clone(),
        };
        let mut rng = Pcg64::seeded(45);
        for _ in 0..20 {
            let set: Vec<bool> = (0..10).map(|_| rng.bernoulli(0.5)).collect();
            let comp: Vec<bool> = set.iter().map(|&b| !b).collect();
            let a = zero_unary.eval(&set);
            let b = zero_unary.eval(&comp);
            assert!((a - b).abs() < 1e-12);
        }
    }
}
