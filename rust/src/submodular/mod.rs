//! Submodular function oracles.
//!
//! Every solver iteration in this library is one *greedy pass*: evaluate the
//! marginal gains of a submodular function `F` along a permutation of the
//! ground set (Edmonds' greedy algorithm — Definition 3 of the paper gives
//! the Lovász extension in exactly this form). The [`Submodular`] trait is
//! therefore designed around `prefix_gains_from`, the batched oracle
//!
//! ```text
//! out[k] = F(B ∪ {j₁..j_{k+1}}) − F(B ∪ {j₁..j_k})
//! ```
//!
//! which every concrete function implements as efficiently as its structure
//! allows (graph cuts: O(E) per pass; dense kernel cuts: O(p²); Gaussian-
//! process mutual information: O(p³) via incremental Cholesky). The `base`
//! set `B` makes the Lemma-1 reduction `F̂(C) = F(Ê ∪ C) − F(Ê)` free to
//! express ([`scaled::ScaledFn`]).
//!
//! All functions are normalized: `F(∅) = 0`.

pub mod concave_card;
pub mod coverage;
pub mod cut;
pub mod facility;
pub mod gaussian_mi;
pub mod iwata;
pub mod kernel_cut;
pub mod modular;
pub mod scaled;

/// Reusable per-pass buffers for [`Submodular::prefix_gains_scratch`].
///
/// Every oracle family needs some transient state per greedy pass
/// (membership weights, coverage flags, client maxima, entropy ladders…).
/// Allocating it per pass puts `malloc` on the solver hot loop — one pass
/// per major iteration, thousands of iterations per solve — so the solver
/// workspace owns one `OracleScratch` and threads it through every pass.
/// The buffers are written before they are read on each call, so a scratch
/// can be shared freely across oracles and problem sizes; oracles resize
/// on entry and never rely on previous contents.
///
/// The scratch also carries the **parallel-oracle handle**: an optional
/// shared [`WorkerPool`](crate::runtime::pool::WorkerPool) installed by
/// [`set_pool`](Self::set_pool). Oracles with a pooled pass (the dense
/// kernel-cut accumulator sweep, the high-degree sparse-cut adjacency
/// walk) fan their bandwidth-bound inner loops over the pool when one is
/// present; the handle changes **when** the arithmetic runs, never the
/// arithmetic itself, so pooled and unpooled passes are bit-identical
/// (certified by `check_gains_match_eval` at t ∈ {1, 4}).
#[derive(Clone, Debug, Default)]
pub struct OracleScratch {
    /// 0/1 membership weights (sparse/dense cut adjacency walks).
    pub mem_f64: Vec<f64>,
    /// Boolean membership / coverage flags.
    pub mem_bool: Vec<bool>,
    /// Primary id list (reduced→original translation, base/rest ids).
    pub ids: Vec<usize>,
    /// Secondary id list (incremental-factor member lists).
    pub ids2: Vec<usize>,
    /// Primary f64 accumulator (kernel row sums, forward entropy ladder).
    pub acc: Vec<f64>,
    /// Secondary f64 accumulator (client maxima, backward entropy ladder).
    pub aux: Vec<f64>,
    /// Tertiary f64 buffer (cross rows for incremental factors; chunk
    /// partials of the pooled adjacency reduction).
    pub aux2: Vec<f64>,
    /// Incremental Cholesky workspace (log-det oracles; the forward and
    /// backward entropy ladders run sequentially, so one factor —
    /// reset between passes — serves both).
    pub chol: crate::linalg::IncrementalCholesky,
    /// Nested scratch for wrapper oracles (`ScaledFn` → inner oracle).
    pub inner: Option<Box<OracleScratch>>,
    /// Shared fork-join pool for pooled oracle passes (`None` = the
    /// sequential path). Wrapper oracles re-propagate the handle into
    /// their nested scratch on every pass (see [`nested`](Self::nested)
    /// and `ScaledFn`), so installing it at the workspace root is enough.
    pub(crate) pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>,
}

impl OracleScratch {
    /// Fresh scratch; buffers grow lazily to whatever each oracle needs.
    pub fn new() -> Self {
        Self::default()
    }

    /// The nested scratch, created on first use (wrapper oracles). The
    /// pool handle is re-propagated on every call so a pool installed
    /// (or removed) after the nested scratch was created still reaches
    /// the inner oracle; the `Arc` clone is allocation-free.
    pub fn nested(&mut self) -> &mut OracleScratch {
        let pool = self.pool.clone();
        let inner = self.inner.get_or_insert_with(Default::default);
        inner.pool = pool;
        inner
    }

    /// Install (or clear) the shared worker pool used by pooled oracle
    /// passes. A `None` handle restores the sequential path; either way
    /// the produced gains are bit-identical — the pool only moves the
    /// same fixed-chunk arithmetic onto more threads.
    pub fn set_pool(
        &mut self,
        pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>,
    ) {
        self.pool = pool;
    }

    /// The installed pool handle, if any (pooled oracle kernels).
    #[inline]
    pub fn pool(&self) -> Option<&std::sync::Arc<crate::runtime::pool::WorkerPool>> {
        self.pool.as_ref()
    }
}

/// A normalized submodular set function `F: 2^V → ℝ` with `F(∅) = 0`.
///
/// Implementations must be deterministic and thread-safe (`Sync`): the
/// experiment coordinator evaluates independent problems from a thread pool.
pub trait Submodular: Sync {
    /// `p = |V|`.
    fn ground_size(&self) -> usize;

    /// `F(A)` for a membership vector of length `ground_size()`.
    fn eval(&self, set: &[bool]) -> f64;

    /// Marginal gains along `order`, starting from `base`:
    /// `out[k] = F(base ∪ {order[..=k]}) − F(base ∪ {order[..k]})`.
    ///
    /// `order` must contain distinct ids not in `base`. The default
    /// implementation materializes each prefix and calls [`eval`]
    /// (O(|order|) evaluations) — override it for anything hot.
    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        assert_eq!(order.len(), out.len());
        let mut set = base.to_vec();
        let mut prev = self.eval(&set);
        for (k, &j) in order.iter().enumerate() {
            debug_assert!(!set[j], "order element {j} already in base/prefix");
            set[j] = true;
            let cur = self.eval(&set);
            out[k] = cur - prev;
            prev = cur;
        }
    }

    /// Marginal gains along `order` starting from the empty set.
    fn prefix_gains(&self, order: &[usize], out: &mut [f64]) {
        let base = vec![false; self.ground_size()];
        self.prefix_gains_from(&base, order, out);
    }

    /// Allocation-free variant of [`prefix_gains_from`]: identical
    /// semantics and **bit-identical results**, but all transient pass
    /// state lives in `scratch`, which the caller owns and reuses.
    ///
    /// This is the solver hot path — `greedy_base_vertex` calls it once
    /// per major iteration. Implementations must not allocate once the
    /// scratch buffers have grown to the working size, and must perform
    /// the same floating-point operations in the same order as
    /// [`prefix_gains_from`] so the two paths stay bit-identical (the
    /// property tests enforce this for every oracle family).
    ///
    /// The default forwards to [`prefix_gains_from`] — correct for
    /// oracles whose gains path is already allocation-free
    /// (`modular`, `iwata`, `concave_card`).
    ///
    /// [`prefix_gains_from`]: Submodular::prefix_gains_from
    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        let _ = scratch;
        self.prefix_gains_from(base, order, out);
    }
}

/// Blanket helpers for any [`Submodular`].
pub trait SubmodularExt: Submodular {
    /// `F(A)` with `A` given as element ids.
    fn eval_ids(&self, ids: &[usize]) -> f64 {
        let mut set = vec![false; self.ground_size()];
        for &i in ids {
            assert!(i < set.len());
            set[i] = true;
        }
        self.eval(&set)
    }

    /// `F(V)`.
    fn eval_full(&self) -> f64 {
        self.eval(&vec![true; self.ground_size()])
    }

    /// Marginal value `F(A ∪ {j}) − F(A)`.
    fn marginal(&self, set: &[bool], j: usize) -> f64 {
        debug_assert!(!set[j]);
        let mut with = set.to_vec();
        with[j] = true;
        self.eval(&with) - self.eval(set)
    }

    /// Spot-check submodularity on random pairs (diminishing returns form):
    /// for A ⊆ B and j ∉ B, `F(A∪j) − F(A) ≥ F(B∪j) − F(B)`.
    /// Returns the worst violation found (≤ `tol` means consistent).
    fn check_submodular(&self, rng: &mut crate::rng::Pcg64, trials: usize) -> f64 {
        let p = self.ground_size();
        let mut worst: f64 = 0.0;
        if p < 2 {
            return 0.0;
        }
        for _ in 0..trials {
            // Random nested pair A ⊆ B and j outside B.
            let mut b = vec![false; p];
            for x in b.iter_mut() {
                *x = rng.bernoulli(0.4);
            }
            let j = rng.below(p);
            b[j] = false;
            let mut a = b.clone();
            for x in a.iter_mut() {
                if *x && rng.bernoulli(0.5) {
                    *x = false;
                }
            }
            let ga = self.marginal(&a, j);
            let gb = self.marginal(&b, j);
            worst = worst.max(gb - ga);
        }
        worst
    }
}

impl<F: Submodular + ?Sized> SubmodularExt for F {}

impl<F: Submodular + ?Sized> Submodular for &F {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn eval(&self, set: &[bool]) -> f64 {
        (**self).eval(set)
    }
    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        (**self).prefix_gains_from(base, order, out)
    }
    fn prefix_gains(&self, order: &[usize], out: &mut [f64]) {
        (**self).prefix_gains(order, out)
    }
    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        (**self).prefix_gains_scratch(base, order, out, scratch)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::rng::Pcg64;

    /// Check `prefix_gains_from` against the default eval-based path for a
    /// bunch of random (base, order) splits, and `prefix_gains_scratch`
    /// against `prefix_gains_from` **bit-for-bit** — including a second
    /// scratch call to catch state leaking between passes. One shared
    /// dirty scratch is reused across all cases, exactly like the solver
    /// hot loop does.
    ///
    /// Every pass is additionally replayed through a **pooled** scratch
    /// (a shared 3-worker [`WorkerPool`] + the calling thread — the
    /// monolithic `t = 4` convention) and certified bit-identical to the
    /// sequential path: the plain scratch is the `t = 1` leg of the
    /// t ∈ {1, 4} matrix, the pooled scratch the `t = 4` leg. Oracles
    /// without a pooled kernel take the identical sequential path, so
    /// the check is trivially true for them and load-bearing for the
    /// SIMD/parallel families (kernel cut, sparse cut).
    pub fn check_gains_match_eval<F: Submodular>(f: &F, seed: u64, tol: f64) {
        let p = f.ground_size();
        let mut rng = Pcg64::seeded(seed);
        let mut scratch = OracleScratch::new();
        let mut pooled_scratch = OracleScratch::new();
        pooled_scratch
            .set_pool(Some(std::sync::Arc::new(crate::runtime::pool::WorkerPool::new(3))));
        for _ in 0..8 {
            let mut base = vec![false; p];
            for x in base.iter_mut() {
                *x = rng.bernoulli(0.25);
            }
            let mut rest: Vec<usize> =
                (0..p).filter(|&i| !base[i]).collect();
            rng.shuffle(&mut rest);
            let mut fast = vec![0.0; rest.len()];
            f.prefix_gains_from(&base, &rest, &mut fast);
            // Default path via eval:
            let mut slow = vec![0.0; rest.len()];
            let mut set = base.clone();
            let mut prev = f.eval(&set);
            for (k, &j) in rest.iter().enumerate() {
                set[j] = true;
                let cur = f.eval(&set);
                slow[k] = cur - prev;
                prev = cur;
            }
            for k in 0..rest.len() {
                assert!(
                    (fast[k] - slow[k]).abs() < tol * (1.0 + slow[k].abs()),
                    "gain {k}: fast {} vs slow {}",
                    fast[k],
                    slow[k]
                );
            }
            // Scratch path: bit-identical to the allocating fast path,
            // on the first call and again with the now-dirty scratch.
            let mut with_scratch = vec![0.0; rest.len()];
            for round in 0..2 {
                with_scratch.iter_mut().for_each(|x| *x = f64::NAN);
                f.prefix_gains_scratch(&base, &rest, &mut with_scratch, &mut scratch);
                for k in 0..rest.len() {
                    assert!(
                        with_scratch[k].to_bits() == fast[k].to_bits(),
                        "scratch gain {k} (round {round}): {} vs {}",
                        with_scratch[k],
                        fast[k]
                    );
                }
            }
            // Pooled scratch path (t = 4): the parallel kernels must be
            // bit-identical to the sequential t = 1 pass above.
            for round in 0..2 {
                with_scratch.iter_mut().for_each(|x| *x = f64::NAN);
                f.prefix_gains_scratch(&base, &rest, &mut with_scratch, &mut pooled_scratch);
                for k in 0..rest.len() {
                    assert!(
                        with_scratch[k].to_bits() == fast[k].to_bits(),
                        "pooled gain {k} (t=4 round {round}): {} vs {}",
                        with_scratch[k],
                        fast[k]
                    );
                }
            }
        }
    }

    /// Assert a function is (numerically) submodular and normalized.
    pub fn check_axioms<F: Submodular>(f: &F, seed: u64, tol: f64) {
        let p = f.ground_size();
        assert!((f.eval(&vec![false; p])).abs() < tol, "F(∅) != 0");
        let mut rng = Pcg64::seeded(seed);
        let worst = f.check_submodular(&mut rng, 200);
        assert!(worst <= tol, "submodularity violated by {worst}");
    }
}

#[cfg(test)]
mod tests {
    use super::modular::ModularFn;
    use super::*;

    #[test]
    fn ext_eval_ids() {
        let f = ModularFn::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.eval_ids(&[0, 2]), 4.0);
        assert_eq!(f.eval_full(), 6.0);
    }

    #[test]
    fn default_prefix_gains_telescopes() {
        let f = ModularFn::new(vec![1.0, -2.0, 0.5]);
        let mut out = vec![0.0; 3];
        f.prefix_gains(&[2, 0, 1], &mut out);
        assert_eq!(out, vec![0.5, 1.0, -2.0]);
    }

    #[test]
    fn dyn_object_safe() {
        let f = ModularFn::new(vec![1.0, 2.0]);
        let d: &dyn Submodular = &f;
        assert_eq!(d.ground_size(), 2);
        assert_eq!(d.eval(&[true, false]), 1.0);
    }

    #[test]
    fn default_scratch_path_matches_allocating_path() {
        let f = ModularFn::new(vec![1.0, -2.0, 0.5]);
        let d: &dyn Submodular = &f;
        let base = [false, false, false];
        let order = [2usize, 0, 1];
        let mut scratch = OracleScratch::new();
        let mut out = [0.0; 3];
        d.prefix_gains_scratch(&base, &order, &mut out, &mut scratch);
        assert_eq!(out, [0.5, 1.0, -2.0]);
    }
}
