//! Submodular function oracles.
//!
//! Every solver iteration in this library is one *greedy pass*: evaluate the
//! marginal gains of a submodular function `F` along a permutation of the
//! ground set (Edmonds' greedy algorithm — Definition 3 of the paper gives
//! the Lovász extension in exactly this form). The [`Submodular`] trait is
//! therefore designed around `prefix_gains_from`, the batched oracle
//!
//! ```text
//! out[k] = F(B ∪ {j₁..j_{k+1}}) − F(B ∪ {j₁..j_k})
//! ```
//!
//! which every concrete function implements as efficiently as its structure
//! allows (graph cuts: O(E) per pass; dense kernel cuts: O(p²); Gaussian-
//! process mutual information: O(p³) via incremental Cholesky). The `base`
//! set `B` makes the Lemma-1 reduction `F̂(C) = F(Ê ∪ C) − F(Ê)` free to
//! express ([`scaled::ScaledFn`]).
//!
//! All functions are normalized: `F(∅) = 0`.

pub mod concave_card;
pub mod coverage;
pub mod cut;
pub mod facility;
pub mod gaussian_mi;
pub mod iwata;
pub mod kernel_cut;
pub mod modular;
pub mod scaled;

/// A normalized submodular set function `F: 2^V → ℝ` with `F(∅) = 0`.
///
/// Implementations must be deterministic and thread-safe (`Sync`): the
/// experiment coordinator evaluates independent problems from a thread pool.
pub trait Submodular: Sync {
    /// `p = |V|`.
    fn ground_size(&self) -> usize;

    /// `F(A)` for a membership vector of length `ground_size()`.
    fn eval(&self, set: &[bool]) -> f64;

    /// Marginal gains along `order`, starting from `base`:
    /// `out[k] = F(base ∪ {order[..=k]}) − F(base ∪ {order[..k]})`.
    ///
    /// `order` must contain distinct ids not in `base`. The default
    /// implementation materializes each prefix and calls [`eval`]
    /// (O(|order|) evaluations) — override it for anything hot.
    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        assert_eq!(order.len(), out.len());
        let mut set = base.to_vec();
        let mut prev = self.eval(&set);
        for (k, &j) in order.iter().enumerate() {
            debug_assert!(!set[j], "order element {j} already in base/prefix");
            set[j] = true;
            let cur = self.eval(&set);
            out[k] = cur - prev;
            prev = cur;
        }
    }

    /// Marginal gains along `order` starting from the empty set.
    fn prefix_gains(&self, order: &[usize], out: &mut [f64]) {
        let base = vec![false; self.ground_size()];
        self.prefix_gains_from(&base, order, out);
    }
}

/// Blanket helpers for any [`Submodular`].
pub trait SubmodularExt: Submodular {
    /// `F(A)` with `A` given as element ids.
    fn eval_ids(&self, ids: &[usize]) -> f64 {
        let mut set = vec![false; self.ground_size()];
        for &i in ids {
            assert!(i < set.len());
            set[i] = true;
        }
        self.eval(&set)
    }

    /// `F(V)`.
    fn eval_full(&self) -> f64 {
        self.eval(&vec![true; self.ground_size()])
    }

    /// Marginal value `F(A ∪ {j}) − F(A)`.
    fn marginal(&self, set: &[bool], j: usize) -> f64 {
        debug_assert!(!set[j]);
        let mut with = set.to_vec();
        with[j] = true;
        self.eval(&with) - self.eval(set)
    }

    /// Spot-check submodularity on random pairs (diminishing returns form):
    /// for A ⊆ B and j ∉ B, `F(A∪j) − F(A) ≥ F(B∪j) − F(B)`.
    /// Returns the worst violation found (≤ `tol` means consistent).
    fn check_submodular(&self, rng: &mut crate::rng::Pcg64, trials: usize) -> f64 {
        let p = self.ground_size();
        let mut worst: f64 = 0.0;
        if p < 2 {
            return 0.0;
        }
        for _ in 0..trials {
            // Random nested pair A ⊆ B and j outside B.
            let mut b = vec![false; p];
            for x in b.iter_mut() {
                *x = rng.bernoulli(0.4);
            }
            let j = rng.below(p);
            b[j] = false;
            let mut a = b.clone();
            for x in a.iter_mut() {
                if *x && rng.bernoulli(0.5) {
                    *x = false;
                }
            }
            let ga = self.marginal(&a, j);
            let gb = self.marginal(&b, j);
            worst = worst.max(gb - ga);
        }
        worst
    }
}

impl<F: Submodular + ?Sized> SubmodularExt for F {}

impl<F: Submodular + ?Sized> Submodular for &F {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn eval(&self, set: &[bool]) -> f64 {
        (**self).eval(set)
    }
    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        (**self).prefix_gains_from(base, order, out)
    }
    fn prefix_gains(&self, order: &[usize], out: &mut [f64]) {
        (**self).prefix_gains(order, out)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::rng::Pcg64;

    /// Check `prefix_gains_from` against the default eval-based path for a
    /// bunch of random (base, order) splits.
    pub fn check_gains_match_eval<F: Submodular>(f: &F, seed: u64, tol: f64) {
        let p = f.ground_size();
        let mut rng = Pcg64::seeded(seed);
        for _ in 0..8 {
            let mut base = vec![false; p];
            for x in base.iter_mut() {
                *x = rng.bernoulli(0.25);
            }
            let mut rest: Vec<usize> =
                (0..p).filter(|&i| !base[i]).collect();
            rng.shuffle(&mut rest);
            let mut fast = vec![0.0; rest.len()];
            f.prefix_gains_from(&base, &rest, &mut fast);
            // Default path via eval:
            let mut slow = vec![0.0; rest.len()];
            let mut set = base.clone();
            let mut prev = f.eval(&set);
            for (k, &j) in rest.iter().enumerate() {
                set[j] = true;
                let cur = f.eval(&set);
                slow[k] = cur - prev;
                prev = cur;
            }
            for k in 0..rest.len() {
                assert!(
                    (fast[k] - slow[k]).abs() < tol * (1.0 + slow[k].abs()),
                    "gain {k}: fast {} vs slow {}",
                    fast[k],
                    slow[k]
                );
            }
        }
    }

    /// Assert a function is (numerically) submodular and normalized.
    pub fn check_axioms<F: Submodular>(f: &F, seed: u64, tol: f64) {
        let p = f.ground_size();
        assert!((f.eval(&vec![false; p])).abs() < tol, "F(∅) != 0");
        let mut rng = Pcg64::seeded(seed);
        let worst = f.check_submodular(&mut rng, 200);
        assert!(worst <= tol, "submodularity violated by {worst}");
    }
}

#[cfg(test)]
mod tests {
    use super::modular::ModularFn;
    use super::*;

    #[test]
    fn ext_eval_ids() {
        let f = ModularFn::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.eval_ids(&[0, 2]), 4.0);
        assert_eq!(f.eval_full(), 6.0);
    }

    #[test]
    fn default_prefix_gains_telescopes() {
        let f = ModularFn::new(vec![1.0, -2.0, 0.5]);
        let mut out = vec![0.0; 3];
        f.prefix_gains(&[2, 0, 1], &mut out);
        assert_eq!(out, vec![0.5, 1.0, -2.0]);
    }

    #[test]
    fn dyn_object_safe() {
        let f = ModularFn::new(vec![1.0, 2.0]);
        let d: &dyn Submodular = &f;
        assert_eq!(d.ground_size(), 2);
        assert_eq!(d.eval(&[true, false]), 1.0);
    }
}
