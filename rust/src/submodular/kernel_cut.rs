//! Dense kernel-cut functions — the fast two-moons objective (§4.1).
//!
//! `F(A) = u(A) + Σ_{i∈A, j∈V∖A} K_ij` with a dense symmetric nonnegative
//! similarity matrix `K` (Gaussian kernel `exp(−α‖x_i−x_j‖²)` in the
//! two-moons experiment) and unary label potentials `u`.
//!
//! This is the O(p²)-per-greedy-pass stand-in for the paper's Gaussian-
//! process mutual-information objective (implemented exactly in
//! [`super::gaussian_mi`], O(p³) per pass): both are a symmetric submodular
//! smoothness term plus the same modular label term, which is the structure
//! the two-moons experiment probes. See DESIGN.md §Substitutions.

use super::{OracleScratch, Submodular};
use crate::linalg::vecops::{add_assign4, sweep4};
use crate::runtime::pool::DisjointSlice;

/// Elements per pooled gains superblock: 8 fused 4-row sweeps. The
/// per-column accumulator op order inside a superblock is exactly the
/// sequential 4-block path's, so the pooled and sequential passes are
/// bit-identical (see `prefix_gains_scratch`).
const SUPERBLOCK: usize = 32;

/// Columns per pooled sweep chunk. The chunk grid is a function of `p`
/// only — never of the worker count — and every `acc[j]` is owned by
/// exactly one chunk, which is what makes the pooled sweep bitwise
/// thread-count-deterministic.
const COL_CHUNK: usize = 512;

/// Below this many columns a pooled dispatch costs more than the sweep;
/// the sequential path runs instead (bit-identical, so the gate is
/// unobservable in the results).
const MIN_POOL_COLS: usize = 128;

/// Dense symmetric cut + unary potentials.
#[derive(Clone, Debug)]
pub struct KernelCutFn {
    p: usize,
    /// Row-major `p × p` symmetric similarity, zero diagonal.
    k: Vec<f64>,
    /// Unary potentials.
    unary: Vec<f64>,
    /// Cached row sums of `k`.
    rowsum: Vec<f64>,
}

impl KernelCutFn {
    /// Build from a dense similarity matrix (row-major `p×p`). The diagonal
    /// is ignored (forced to zero); the matrix must be symmetric and
    /// nonnegative.
    pub fn new(p: usize, mut k: Vec<f64>, unary: Vec<f64>) -> Self {
        assert_eq!(k.len(), p * p);
        assert_eq!(unary.len(), p);
        for i in 0..p {
            k[i * p + i] = 0.0;
        }
        for i in 0..p {
            for j in (i + 1)..p {
                let a = k[i * p + j];
                let b = k[j * p + i];
                assert!(a >= 0.0 && b >= 0.0, "negative similarity");
                assert!(
                    (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                    "similarity not symmetric at ({i},{j})"
                );
            }
        }
        let rowsum = (0..p).map(|i| k[i * p..(i + 1) * p].iter().sum()).collect();
        KernelCutFn { p, k, unary, rowsum }
    }

    /// Similarity row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.k[i * self.p..(i + 1) * self.p]
    }

    /// Unary potentials.
    pub fn unary(&self) -> &[f64] {
        &self.unary
    }
}

impl Submodular for KernelCutFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let mut v = 0.0;
        for i in 0..self.p {
            if set[i] {
                v += self.unary[i];
                let row = self.row(i);
                for j in 0..self.p {
                    if !set[j] {
                        v += row[j];
                    }
                }
            }
        }
        v
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // acc[v] = Σ_{j ∈ A} K_vj, maintained as the prefix grows.
        // gain(v) = u_v + rowsum_v − 2 · acc[v].
        //
        // The accumulator update is blocked 4 rows at a time: one fused
        // sweep `acc[j] += (r0[j] + r1[j]) + (r2[j] + r3[j])`
        // (`vecops::sweep4`) reads `acc` once per 4 rows instead of once
        // per row, cutting HBM/DRAM traffic from 3 to ~1.5 streams per
        // row (the pass is bandwidth-bound — see EXPERIMENTS.md §Perf).
        // The in-block gain corrections are the scalar K[v_e][v_i] terms
        // for e < i within the block.
        //
        // With a pool installed the pass runs in SUPERBLOCK-element
        // groups: the gains of a whole superblock are computed up front
        // on this thread by replaying the exact 4-block accumulator
        // algebra at the 32 needed columns (fused pairs for completed
        // in-superblock 4-blocks, left-associated singles inside the
        // element's own 4-block — the identical FP expression the
        // sequential path evaluates), then ONE pooled column-chunked
        // sweep folds all 8 row quartets into `acc`. Every `acc[j]` is
        // owned by exactly one chunk and sees the identical per-column
        // op sequence, so the pooled pass is bit-identical to the
        // sequential pass at every thread count.
        let p = self.p;
        let OracleScratch { acc, ids, pool, .. } = scratch;
        let pool = pool.clone();
        acc.clear();
        acc.resize(p, 0.0);
        // Base accumulation: row-by-row adds. Per-column the op order is
        // the base row order — identical sequentially and column-chunked.
        // The base-row id list is only materialized for the pooled arm,
        // keeping the t = 1 path allocation-identical to the unpooled
        // engine.
        let pooled_base = match &pool {
            Some(pool) if p >= MIN_POOL_COLS => {
                ids.clear();
                ids.extend(base.iter().enumerate().filter_map(|(j, &b)| b.then_some(j)));
                if ids.len() >= 8 {
                    let accs = DisjointSlice::new(acc);
                    let rows: &[usize] = ids;
                    pool.run_chunks(p, COL_CHUNK, &|r: std::ops::Range<usize>| {
                        // SAFETY: run_chunks ranges are disjoint.
                        let a = unsafe { accs.slice_mut(r.clone()) };
                        for &i in rows {
                            add_assign4(a, &self.k[i * p..][r.clone()]);
                        }
                    });
                    true
                } else {
                    false
                }
            }
            _ => false,
        };
        if !pooled_base {
            for (j, &inb) in base.iter().enumerate() {
                if inb {
                    add_assign4(acc, self.row(j));
                }
            }
        }
        let n = order.len();
        let mut k = 0;
        if let Some(pool) = &pool {
            if p >= MIN_POOL_COLS {
                while k + SUPERBLOCK <= n {
                    let blk = &order[k..k + SUPERBLOCK];
                    for (l, &vl) in blk.iter().enumerate() {
                        // Replay of the sequential accumulator at column
                        // vl: completed 4-blocks enter as fused pairs
                        // (exactly sweep4's per-element expression),
                        // the element's own block as ordered singles.
                        let mut a = acc[vl];
                        let full = l / 4;
                        for b in 0..full {
                            let r0 = self.k[blk[4 * b] * p + vl];
                            let r1 = self.k[blk[4 * b + 1] * p + vl];
                            let r2 = self.k[blk[4 * b + 2] * p + vl];
                            let r3 = self.k[blk[4 * b + 3] * p + vl];
                            a += (r0 + r1) + (r2 + r3);
                        }
                        for &ve in &blk[4 * full..l] {
                            a += self.k[ve * p + vl];
                        }
                        out[k + l] = self.unary[vl] + self.rowsum[vl] - 2.0 * a;
                    }
                    let accs = DisjointSlice::new(acc);
                    pool.run_chunks(p, COL_CHUNK, &|r: std::ops::Range<usize>| {
                        // SAFETY: run_chunks ranges are disjoint.
                        let a = unsafe { accs.slice_mut(r.clone()) };
                        for b in 0..SUPERBLOCK / 4 {
                            sweep4(
                                a,
                                &self.k[blk[4 * b] * p..][r.clone()],
                                &self.k[blk[4 * b + 1] * p..][r.clone()],
                                &self.k[blk[4 * b + 2] * p..][r.clone()],
                                &self.k[blk[4 * b + 3] * p..][r.clone()],
                            );
                        }
                    });
                    k += SUPERBLOCK;
                }
            }
        }
        // Sequential 4-blocks: the whole pass when unpooled, the <32
        // element tail after the pooled superblocks otherwise.
        while k + 4 <= n {
            let v = [order[k], order[k + 1], order[k + 2], order[k + 3]];
            // Gains with in-block corrections (acc is pre-block).
            out[k] = self.unary[v[0]] + self.rowsum[v[0]] - 2.0 * acc[v[0]];
            out[k + 1] = self.unary[v[1]] + self.rowsum[v[1]]
                - 2.0 * (acc[v[1]] + self.k[v[0] * p + v[1]]);
            out[k + 2] = self.unary[v[2]] + self.rowsum[v[2]]
                - 2.0 * (acc[v[2]] + self.k[v[0] * p + v[2]] + self.k[v[1] * p + v[2]]);
            out[k + 3] = self.unary[v[3]] + self.rowsum[v[3]]
                - 2.0
                    * (acc[v[3]]
                        + self.k[v[0] * p + v[3]]
                        + self.k[v[1] * p + v[3]]
                        + self.k[v[2] * p + v[3]]);
            // Fused 4-row accumulator sweep.
            sweep4(
                acc,
                &self.k[v[0] * p..v[0] * p + p],
                &self.k[v[1] * p..v[1] * p + p],
                &self.k[v[2] * p..v[2] * p + p],
                &self.k[v[3] * p..v[3] * p + p],
            );
            k += 4;
        }
        while k < n {
            let v = order[k];
            out[k] = self.unary[v] + self.rowsum[v] - 2.0 * acc[v];
            add_assign4(acc, self.row(v));
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    fn random_kernel_cut(p: usize, seed: u64) -> KernelCutFn {
        let mut rng = Pcg64::seeded(seed);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        KernelCutFn::new(p, k, unary)
    }

    #[test]
    fn axioms_and_gains() {
        let f = random_kernel_cut(11, 51);
        check_axioms(&f, 52, 1e-9);
        check_gains_match_eval(&f, 53, 1e-9);
    }

    #[test]
    fn matches_sparse_cut_on_same_graph() {
        use crate::submodular::cut::CutFn;
        let p = 8;
        let mut rng = Pcg64::seeded(54);
        let mut k = vec![0.0; p * p];
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
                edges.push((i, j, w));
            }
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        let dense = KernelCutFn::new(p, k, unary.clone());
        let sparse = CutFn::from_edges(p, &edges, unary);
        for _ in 0..30 {
            let set: Vec<bool> = (0..p).map(|_| rng.bernoulli(0.5)).collect();
            assert!((dense.eval(&set) - sparse.eval(&set)).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_and_full_values() {
        let f = random_kernel_cut(6, 55);
        assert_eq!(f.eval_ids(&[]), 0.0);
        let full = f.eval_full();
        let unary_sum: f64 = f.unary().iter().sum();
        assert!((full - unary_sum).abs() < 1e-9);
    }

    #[test]
    fn pooled_superblock_pass_is_bit_identical_at_scale() {
        // The unit-test sizes above sit below MIN_POOL_COLS, so this is
        // the test where the pooled superblock path actually runs: a
        // p ≥ 128 instance, random base/order splits (including a ragged
        // non-multiple-of-SUPERBLOCK tail), pooled scratches at 2 and 4
        // lanes vs the sequential scratch — bitwise.
        use crate::rng::Pcg64;
        use crate::runtime::pool::WorkerPool;
        use crate::submodular::OracleScratch;
        use std::sync::Arc;
        let p = 192;
        let f = random_kernel_cut(p, 56);
        let mut rng = Pcg64::seeded(57);
        let mut seq = OracleScratch::new();
        let mut pooled: Vec<OracleScratch> = [2usize, 4]
            .iter()
            .map(|&t| {
                let mut s = OracleScratch::new();
                s.set_pool(Some(Arc::new(WorkerPool::new(t - 1))));
                s
            })
            .collect();
        for case in 0..6 {
            let mut base = vec![false; p];
            for x in base.iter_mut() {
                *x = rng.bernoulli(0.2);
            }
            let mut order: Vec<usize> = (0..p).filter(|&i| !base[i]).collect();
            rng.shuffle(&mut order);
            if case % 2 == 0 {
                order.truncate(order.len() - order.len() % 7); // ragged tail
            }
            let mut expect = vec![0.0; order.len()];
            f.prefix_gains_scratch(&base, &order, &mut expect, &mut seq);
            let mut got = vec![f64::NAN; order.len()];
            for (ti, s) in pooled.iter_mut().enumerate() {
                got.iter_mut().for_each(|x| *x = f64::NAN);
                f.prefix_gains_scratch(&base, &order, &mut got, s);
                for k in 0..order.len() {
                    assert_eq!(
                        got[k].to_bits(),
                        expect[k].to_bits(),
                        "case {case}, lane set {ti}, gain {k}"
                    );
                }
            }
        }
    }
}
