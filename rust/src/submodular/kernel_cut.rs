//! Dense kernel-cut functions — the fast two-moons objective (§4.1).
//!
//! `F(A) = u(A) + Σ_{i∈A, j∈V∖A} K_ij` with a dense symmetric nonnegative
//! similarity matrix `K` (Gaussian kernel `exp(−α‖x_i−x_j‖²)` in the
//! two-moons experiment) and unary label potentials `u`.
//!
//! This is the O(p²)-per-greedy-pass stand-in for the paper's Gaussian-
//! process mutual-information objective (implemented exactly in
//! [`super::gaussian_mi`], O(p³) per pass): both are a symmetric submodular
//! smoothness term plus the same modular label term, which is the structure
//! the two-moons experiment probes. See DESIGN.md §Substitutions.

use super::{OracleScratch, Submodular};

/// Dense symmetric cut + unary potentials.
#[derive(Clone, Debug)]
pub struct KernelCutFn {
    p: usize,
    /// Row-major `p × p` symmetric similarity, zero diagonal.
    k: Vec<f64>,
    /// Unary potentials.
    unary: Vec<f64>,
    /// Cached row sums of `k`.
    rowsum: Vec<f64>,
}

impl KernelCutFn {
    /// Build from a dense similarity matrix (row-major `p×p`). The diagonal
    /// is ignored (forced to zero); the matrix must be symmetric and
    /// nonnegative.
    pub fn new(p: usize, mut k: Vec<f64>, unary: Vec<f64>) -> Self {
        assert_eq!(k.len(), p * p);
        assert_eq!(unary.len(), p);
        for i in 0..p {
            k[i * p + i] = 0.0;
        }
        for i in 0..p {
            for j in (i + 1)..p {
                let a = k[i * p + j];
                let b = k[j * p + i];
                assert!(a >= 0.0 && b >= 0.0, "negative similarity");
                assert!(
                    (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                    "similarity not symmetric at ({i},{j})"
                );
            }
        }
        let rowsum = (0..p).map(|i| k[i * p..(i + 1) * p].iter().sum()).collect();
        KernelCutFn { p, k, unary, rowsum }
    }

    /// Similarity row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.k[i * self.p..(i + 1) * self.p]
    }

    /// Unary potentials.
    pub fn unary(&self) -> &[f64] {
        &self.unary
    }
}

impl Submodular for KernelCutFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let mut v = 0.0;
        for i in 0..self.p {
            if set[i] {
                v += self.unary[i];
                let row = self.row(i);
                for j in 0..self.p {
                    if !set[j] {
                        v += row[j];
                    }
                }
            }
        }
        v
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // acc[v] = Σ_{j ∈ A} K_vj, maintained as the prefix grows.
        // gain(v) = u_v + rowsum_v − 2 · acc[v].
        //
        // The accumulator update is blocked 4 rows at a time: one fused
        // sweep `acc[j] += r0[j] + r1[j] + r2[j] + r3[j]` reads `acc` once
        // per 4 rows instead of once per row, cutting HBM/DRAM traffic
        // from 3 to ~1.5 streams per row (the pass is bandwidth-bound —
        // see EXPERIMENTS.md §Perf). The in-block gain corrections are
        // the scalar K[v_e][v_i] terms for e < i within the block.
        let p = self.p;
        let acc = &mut scratch.acc;
        acc.clear();
        acc.resize(p, 0.0);
        for (j, &inb) in base.iter().enumerate() {
            if inb {
                let row = self.row(j);
                for (a, &kij) in acc.iter_mut().zip(row) {
                    *a += kij;
                }
            }
        }
        let n = order.len();
        let mut k = 0;
        while k + 4 <= n {
            let v = [order[k], order[k + 1], order[k + 2], order[k + 3]];
            // Gains with in-block corrections (acc is pre-block).
            out[k] = self.unary[v[0]] + self.rowsum[v[0]] - 2.0 * acc[v[0]];
            out[k + 1] = self.unary[v[1]] + self.rowsum[v[1]]
                - 2.0 * (acc[v[1]] + self.k[v[0] * p + v[1]]);
            out[k + 2] = self.unary[v[2]] + self.rowsum[v[2]]
                - 2.0 * (acc[v[2]] + self.k[v[0] * p + v[2]] + self.k[v[1] * p + v[2]]);
            out[k + 3] = self.unary[v[3]] + self.rowsum[v[3]]
                - 2.0
                    * (acc[v[3]]
                        + self.k[v[0] * p + v[3]]
                        + self.k[v[1] * p + v[3]]
                        + self.k[v[2] * p + v[3]]);
            // Fused 4-row accumulator sweep.
            let (r0, r1, r2, r3) = (
                &self.k[v[0] * p..v[0] * p + p],
                &self.k[v[1] * p..v[1] * p + p],
                &self.k[v[2] * p..v[2] * p + p],
                &self.k[v[3] * p..v[3] * p + p],
            );
            for j in 0..p {
                acc[j] += (r0[j] + r1[j]) + (r2[j] + r3[j]);
            }
            k += 4;
        }
        while k < n {
            let v = order[k];
            out[k] = self.unary[v] + self.rowsum[v] - 2.0 * acc[v];
            let row = self.row(v);
            for (a, &kvj) in acc.iter_mut().zip(row) {
                *a += kvj;
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    fn random_kernel_cut(p: usize, seed: u64) -> KernelCutFn {
        let mut rng = Pcg64::seeded(seed);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        KernelCutFn::new(p, k, unary)
    }

    #[test]
    fn axioms_and_gains() {
        let f = random_kernel_cut(11, 51);
        check_axioms(&f, 52, 1e-9);
        check_gains_match_eval(&f, 53, 1e-9);
    }

    #[test]
    fn matches_sparse_cut_on_same_graph() {
        use crate::submodular::cut::CutFn;
        let p = 8;
        let mut rng = Pcg64::seeded(54);
        let mut k = vec![0.0; p * p];
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
                edges.push((i, j, w));
            }
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        let dense = KernelCutFn::new(p, k, unary.clone());
        let sparse = CutFn::from_edges(p, &edges, unary);
        for _ in 0..30 {
            let set: Vec<bool> = (0..p).map(|_| rng.bernoulli(0.5)).collect();
            assert!((dense.eval(&set) - sparse.eval(&set)).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_and_full_values() {
        let f = random_kernel_cut(6, 55);
        assert_eq!(f.eval_ids(&[]), 0.0);
        let full = f.eval_full();
        let unary_sum: f64 = f.unary().iter().sum();
        assert!((full - unary_sum).abs() < 1e-9);
    }
}
