//! Iwata's test function — the standard synthetic SFM benchmark.
//!
//! `F(A) = |A| · |V∖A| − Σ_{j∈A} (5j − 2p)` with `j` 1-indexed. The first
//! term is the cut of the complete unit-weight graph (symmetric submodular);
//! the second is modular, tilted so the minimizer is a nontrivial prefix.
//! Widely used to stress min-norm-point implementations (Fujishige &
//! Isotani 2011).

use super::Submodular;

/// Iwata's test function on `V = {1..p}` (stored 0-indexed).
#[derive(Clone, Debug)]
pub struct IwataFn {
    p: usize,
}

impl IwataFn {
    /// Create the function for ground-set size `p`.
    pub fn new(p: usize) -> Self {
        IwataFn { p }
    }

    #[inline]
    fn modular_term(&self, j0: usize) -> f64 {
        // j is 1-indexed in the classical definition.
        let j = (j0 + 1) as f64;
        5.0 * j - 2.0 * self.p as f64
    }
}

impl Submodular for IwataFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let a = set.iter().filter(|&&b| b).count() as f64;
        let cut = a * (self.p as f64 - a);
        let modular: f64 = set
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(j, _)| self.modular_term(j))
            .sum();
        cut - modular
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let p = self.p as f64;
        let mut k = base.iter().filter(|&&b| b).count() as f64;
        for (o, &j) in out.iter_mut().zip(order) {
            // |A| k -> k+1 changes the cut term by p - 2k - 1.
            *o = (p - 2.0 * k - 1.0) - self.modular_term(j);
            k += 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn axioms_and_gains() {
        let f = IwataFn::new(17);
        check_axioms(&f, 21, 1e-9);
        check_gains_match_eval(&f, 22, 1e-9);
    }

    #[test]
    fn known_small_values() {
        let f = IwataFn::new(4);
        // F({1}) (0-indexed id 0): 1*3 - (5*1 - 8) = 3 - (-3) = 6.
        assert_eq!(f.eval_ids(&[0]), 6.0);
        // F(V) = 0 - Σ(5j - 2p) = -(5*10 - 8*4) = -18.
        assert_eq!(f.eval_full(), -18.0);
    }

    #[test]
    fn minimum_is_negative_for_moderate_p() {
        // The tilt guarantees a nontrivial minimizer for p ≥ 3.
        let f = IwataFn::new(10);
        let full = f.eval_full();
        assert!(full < 0.0);
    }
}
