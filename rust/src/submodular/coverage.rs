//! Weighted coverage minus modular cost.
//!
//! `F(A) = Σ_{u ∈ ∪_{j∈A} S_j} w_u − c(A)`: the classic monotone submodular
//! coverage value of the sets selected by `A`, minus a per-element cost.
//! Minimizing `−(coverage − cost)`... wait, we *minimize* `F`; with
//! negative costs SFM selects elements whose cost savings outweigh the
//! (submodular, hence diminishing) coverage they add. A good stress family
//! for the screening rules because the optimum mixes "obviously in",
//! "obviously out", and genuinely coupled elements.

use super::{OracleScratch, Submodular};
use crate::linalg::vecops::cover_gain4;

/// Weighted set coverage with modular costs.
#[derive(Clone, Debug)]
pub struct CoverageFn {
    /// `sets[j]` = items covered by element `j`.
    sets: Vec<Vec<u32>>,
    /// Item weights (`w_u ≥ 0`).
    item_w: Vec<f64>,
    /// Per-element modular cost (subtracted).
    cost: Vec<f64>,
}

impl CoverageFn {
    /// Build from covering sets, nonnegative item weights, and costs.
    /// Repeated items within one set are collapsed to their first
    /// occurrence (a set cannot contain an item twice — this matches
    /// what the old branchy gains walk computed for such inputs), which
    /// establishes the distinct-items precondition of the branchless
    /// gains kernel.
    pub fn new(mut sets: Vec<Vec<u32>>, item_w: Vec<f64>, cost: Vec<f64>) -> Self {
        assert_eq!(sets.len(), cost.len());
        let mut seen = vec![false; item_w.len()];
        for s in sets.iter_mut() {
            s.retain(|&u| {
                assert!((u as usize) < item_w.len());
                let fresh = !seen[u as usize];
                seen[u as usize] = true;
                fresh
            });
            for &u in s.iter() {
                seen[u as usize] = false;
            }
        }
        assert!(item_w.iter().all(|&w| w >= 0.0));
        CoverageFn { sets, item_w, cost }
    }

    /// Random instance (used by tests and ablation benches).
    pub fn random(
        p: usize,
        items: usize,
        per_set: usize,
        rng: &mut crate::rng::Pcg64,
    ) -> Self {
        let sets = (0..p)
            .map(|_| {
                let mut s: Vec<u32> =
                    rng.sample_indices(items, per_set.min(items)).iter().map(|&x| x as u32).collect();
                s.sort_unstable();
                s
            })
            .collect();
        let item_w = rng.uniform_vec(items, 0.0, 1.0);
        let cost = rng.uniform_vec(p, 0.0, 2.0);
        CoverageFn::new(sets, item_w, cost)
    }
}

impl Submodular for CoverageFn {
    fn ground_size(&self) -> usize {
        self.sets.len()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.sets.len());
        let mut covered = vec![false; self.item_w.len()];
        let mut value = 0.0;
        for (j, &b) in set.iter().enumerate() {
            if b {
                value -= self.cost[j];
                for &u in &self.sets[j] {
                    if !covered[u as usize] {
                        covered[u as usize] = true;
                        value += self.item_w[u as usize];
                    }
                }
            }
        }
        value
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // `covered` is item-indexed (not ground-set-indexed) and rebuilt
        // from `base` on entry. The per-element gain walk is the
        // branchless 4-lane `vecops::cover_gain4` kernel (items within a
        // set are distinct — asserted at construction — so reading the
        // flag before writing it is exact).
        let covered = &mut scratch.mem_bool;
        covered.clear();
        covered.resize(self.item_w.len(), false);
        for (j, &b) in base.iter().enumerate() {
            if b {
                for &u in &self.sets[j] {
                    covered[u as usize] = true;
                }
            }
        }
        for (o, &j) in out.iter_mut().zip(order) {
            *o = cover_gain4(&self.sets[j], &self.item_w, covered) - self.cost[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    #[test]
    fn axioms_and_gains() {
        let mut rng = Pcg64::seeded(71);
        let f = CoverageFn::random(10, 25, 5, &mut rng);
        check_axioms(&f, 72, 1e-9);
        check_gains_match_eval(&f, 73, 1e-12);
    }

    #[test]
    fn duplicate_items_within_a_set_collapse() {
        // A repeated item contributes once — same value the branchy walk
        // historically produced; the constructor dedup makes it hold for
        // the branchless kernel too.
        let f = CoverageFn::new(vec![vec![0, 1, 0]], vec![1.0, 2.0], vec![0.25]);
        assert!((f.eval_ids(&[0]) - 2.75).abs() < 1e-12); // 1 + 2 − 0.25
        let mut out = [0.0];
        f.prefix_gains(&[0], &mut out);
        assert!((out[0] - 2.75).abs() < 1e-12);
    }

    #[test]
    fn simple_instance() {
        // Two elements covering overlapping items.
        let f = CoverageFn::new(
            vec![vec![0, 1], vec![1, 2]],
            vec![1.0, 2.0, 4.0],
            vec![0.5, 0.5],
        );
        assert_eq!(f.eval_ids(&[0]), 2.5); // 1+2-0.5
        assert_eq!(f.eval_ids(&[1]), 5.5); // 2+4-0.5
        assert_eq!(f.eval_full(), 6.0); // 1+2+4-1
    }
}
