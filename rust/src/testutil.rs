//! Minimal property-testing harness.
//!
//! `proptest` is not available in the offline build environment, so the
//! test suites use this small substitute: run a property over many seeded
//! random cases and, on failure, report the seed + case index so the case
//! can be replayed deterministically. No shrinking — cases are generated
//! small to begin with.

use crate::rng::Pcg64;

/// Effective case count: `SFM_PROP_CASES` caps every `forall` loop so
/// slow interpreters can run the property suites end to end — the Miri
/// CI leg exports `SFM_PROP_CASES=2` (with `-Zmiri-disable-isolation`
/// so the env read is permitted). Seeds depend only on the case index,
/// so a capped run executes a prefix of the full run's cases.
fn effective_cases(cases: usize) -> usize {
    match std::env::var("SFM_PROP_CASES") {
        Ok(v) => match v.parse::<usize>() {
            Ok(cap) if cap > 0 => cases.min(cap),
            _ => cases,
        },
        Err(_) => cases,
    }
}

/// Run `prop` over `cases` seeded random inputs produced by `gen`.
///
/// Panics with the case index and seed on the first failure, so
/// `forall(64, |rng| ...)` failures are reproducible by construction.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..effective_cases(cases) {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property receives the RNG directly (for
/// properties that both generate and check).
pub fn forall_rng(cases: usize, mut prop: impl FnMut(&mut Pcg64) -> Result<(), String>) {
    for case in 0..effective_cases(cases) {
        let seed = 0xBADD_CAFE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats are within `tol`, with a useful message.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Membership vector (characteristic vector as bools) from sorted ids.
pub fn set_from_ids(p: usize, ids: &[usize]) -> Vec<bool> {
    let mut m = vec![false; p];
    for &i in ids {
        m[i] = true;
    }
    m
}

/// Sorted ids from a membership vector.
pub fn ids_from_set(set: &[bool]) -> Vec<usize> {
    set.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            32,
            |rng| rng.uniform(-1.0, 1.0),
            |x| {
                if x.abs() <= 1.0 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(8, |rng| rng.next_f64(), |_| Err("always fails".into()));
    }

    #[test]
    fn set_roundtrip() {
        let ids = vec![0, 3, 4];
        let set = set_from_ids(6, &ids);
        assert_eq!(ids_from_set(&set), ids);
    }
}
