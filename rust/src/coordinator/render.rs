//! Minimal image output: binary PPM (P6) writers for the figure
//! reproductions — segmentation masks, grayscale scenes, and two-moons
//! scatter snapshots — with zero external dependencies.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// An RGB raster.
#[derive(Clone, Debug)]
pub struct Raster {
    /// Width in pixels.
    pub w: usize,
    /// Height in pixels.
    pub h: usize,
    /// RGB bytes, row-major, 3 per pixel.
    pub data: Vec<u8>,
}

impl Raster {
    /// Solid-color raster.
    pub fn filled(w: usize, h: usize, rgb: [u8; 3]) -> Self {
        let mut data = Vec::with_capacity(w * h * 3);
        for _ in 0..w * h {
            data.extend_from_slice(&rgb);
        }
        Raster { w, h, data }
    }

    /// Set one pixel (no-op out of bounds — simplifies scatter plotting).
    pub fn set(&mut self, x: usize, y: usize, rgb: [u8; 3]) {
        if x < self.w && y < self.h {
            let i = (y * self.w + x) * 3;
            self.data[i..i + 3].copy_from_slice(&rgb);
        }
    }

    /// Draw a filled disc (for scatter markers).
    pub fn disc(&mut self, cx: f64, cy: f64, r: f64, rgb: [u8; 3]) {
        let lo_x = (cx - r).floor().max(0.0) as usize;
        let hi_x = (cx + r).ceil().min(self.w as f64) as usize;
        let lo_y = (cy - r).floor().max(0.0) as usize;
        let hi_y = (cy + r).ceil().min(self.h as f64) as usize;
        for y in lo_y..hi_y {
            for x in lo_x..hi_x {
                let dx = x as f64 + 0.5 - cx;
                let dy = y as f64 + 0.5 - cy;
                if dx * dx + dy * dy <= r * r {
                    self.set(x, y, rgb);
                }
            }
        }
    }

    /// Write as binary PPM (P6).
    pub fn write_ppm(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        write!(f, "P6\n{} {}\n255\n", self.w, self.h)?;
        f.write_all(&self.data)?;
        Ok(())
    }
}

/// Render a grayscale scene (values in [0,1], row-major `h×w`).
pub fn grayscale(h: usize, w: usize, values: &[f64]) -> Raster {
    assert_eq!(values.len(), h * w);
    let mut r = Raster::filled(w, h, [0, 0, 0]);
    for y in 0..h {
        for x in 0..w {
            let v = (values[y * w + x].clamp(0.0, 1.0) * 255.0) as u8;
            r.set(x, y, [v, v, v]);
        }
    }
    r
}

/// Render a binary mask over a grayscale scene (mask pixels tinted red).
pub fn mask_overlay(h: usize, w: usize, values: &[f64], mask: &[bool]) -> Raster {
    assert_eq!(mask.len(), h * w);
    let mut r = grayscale(h, w, values);
    for y in 0..h {
        for x in 0..w {
            if mask[y * w + x] {
                let i = (y * w + x) * 3;
                let g = r.data[i];
                r.data[i] = 255;
                r.data[i + 1] = g / 2;
                r.data[i + 2] = g / 2;
            }
        }
    }
    r
}

/// Scatter statuses for [`scatter`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointStatus {
    /// Certified active (magenta, as in the paper's Figure 3).
    Active,
    /// Certified inactive (blue).
    Inactive,
    /// Undecided (cyan).
    Unknown,
}

/// Render a two-moons-style scatter (auto-scaled to the canvas) — the
/// paper's Figure 3 panels.
pub fn scatter(points: &[[f64; 2]], status: &[PointStatus], size: usize) -> Raster {
    assert_eq!(points.len(), status.len());
    let mut raster = Raster::filled(size, size, [255, 255, 255]);
    if points.is_empty() {
        return raster;
    }
    let (mut min_x, mut max_x) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut min_y, mut max_y) = (f64::INFINITY, f64::NEG_INFINITY);
    for p in points {
        min_x = min_x.min(p[0]);
        max_x = max_x.max(p[0]);
        min_y = min_y.min(p[1]);
        max_y = max_y.max(p[1]);
    }
    let pad = 0.05;
    let sx = (1.0 - 2.0 * pad) * size as f64 / (max_x - min_x).max(1e-9);
    let sy = (1.0 - 2.0 * pad) * size as f64 / (max_y - min_y).max(1e-9);
    let s = sx.min(sy);
    let r = (size as f64 / 120.0).max(1.5);
    for (p, st) in points.iter().zip(status) {
        let x = pad * size as f64 + (p[0] - min_x) * s;
        let y = size as f64 - (pad * size as f64 + (p[1] - min_y) * s);
        let rgb = match st {
            PointStatus::Active => [214, 40, 160],
            PointStatus::Inactive => [40, 60, 214],
            PointStatus::Unknown => [90, 200, 210],
        };
        raster.disc(x, y, r, rgb);
    }
    raster
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_header_and_size() {
        let r = Raster::filled(7, 5, [1, 2, 3]);
        let dir = std::env::temp_dir().join("sfm_render_test");
        let path = dir.join("t.ppm");
        r.write_ppm(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n7 5\n255\n"));
        assert_eq!(bytes.len(), b"P6\n7 5\n255\n".len() + 7 * 5 * 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grayscale_maps_values() {
        let r = grayscale(1, 2, &[0.0, 1.0]);
        assert_eq!(&r.data[0..3], &[0, 0, 0]);
        assert_eq!(&r.data[3..6], &[255, 255, 255]);
    }

    #[test]
    fn mask_overlay_tints_red() {
        let r = mask_overlay(1, 2, &[0.5, 0.5], &[false, true]);
        assert_eq!(r.data[0], r.data[1]); // untouched gray
        assert_eq!(r.data[3], 255); // tinted
        assert!(r.data[4] < 255);
    }

    #[test]
    fn scatter_draws_within_canvas() {
        let pts = vec![[0.0, 0.0], [1.0, 1.0], [-1.0, 2.0]];
        let st = vec![PointStatus::Active, PointStatus::Inactive, PointStatus::Unknown];
        let r = scatter(&pts, &st, 64);
        assert_eq!(r.data.len(), 64 * 64 * 3);
        // Not all white: markers were drawn.
        assert!(r.data.iter().any(|&b| b != 255));
    }

    #[test]
    fn disc_clips_at_edges() {
        let mut r = Raster::filled(4, 4, [0, 0, 0]);
        r.disc(0.0, 0.0, 10.0, [9, 9, 9]); // way out of bounds — must not panic
        assert!(r.data.iter().any(|&b| b == 9));
    }
}
