//! The paper's evaluation, as reusable experiment functions.
//!
//! Every table and figure of the paper maps to one function here (see the
//! per-experiment index in DESIGN.md); the `cargo bench` targets and the
//! CLI subcommands are thin wrappers. Each function writes CSVs under
//! `cfg.out_dir` and returns the rendered table for the terminal.

use super::jobs::{solver_choice, BackendChoice, JobSpec, WorkloadSpec};
use super::report::{fnum, write_csv_rows, Table};
use crate::decompose::DecomposeOptions;
use crate::screening::iaes::{IaesOptions, IaesReport};
use crate::screening::RuleSet;
use crate::submodular::Submodular;
use crate::workloads::images::benchmark_suite;
use crate::workloads::two_moons::{TwoMoons, TwoMoonsParams};
use anyhow::Result;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Shared bench configuration (CLI/config-file driven).
#[derive(Clone)]
pub struct BenchConfig {
    /// Two-moons sizes (paper: 200..1000; defaults scaled down — see
    /// DESIGN.md §Substitutions).
    pub sizes: Vec<usize>,
    /// Image scale multiplier (1.0 ≈ 2–4k pixels; paper ≈ 4.0).
    pub image_scale: f64,
    /// Duality-gap accuracy ε.
    pub eps: f64,
    /// Trigger decay ρ.
    pub rho: f64,
    /// Workload seed.
    pub seed: u64,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    /// Screening backend.
    pub backend: BackendChoice,
    /// Use the exact GP mutual-information objective for two-moons.
    pub use_mi: bool,
    /// Iteration cap per solve.
    pub max_iters: usize,
    /// Solver name (`minnorm` | `fw` | `plain-fw`).
    pub solver: String,
    /// Suppress progress printing.
    pub quiet: bool,
    /// Deferred-contraction threshold (see [`IaesOptions`]).
    pub min_reduction_frac: f64,
    /// Lazily materialized screener, shared across every variant run so
    /// PJRT executables compile exactly once per bucket.
    screener_cache: std::sync::OnceLock<Option<std::sync::Arc<dyn crate::screening::Screener>>>,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            sizes: vec![100, 200, 300, 400],
            image_scale: 1.0,
            eps: 1e-6,
            rho: 0.5,
            seed: 2018,
            out_dir: PathBuf::from("bench_out"),
            // The rule evaluation is O(p) flops; below p ~ 1e5 the PJRT
            // call overhead dominates on CPU, so timing benches default to
            // the rust backend. `--backend xla` exercises the compiled
            // kernel (and the micro bench quantifies the crossover).
            backend: BackendChoice::Rust,
            use_mi: false,
            max_iters: 200_000,
            solver: "minnorm".into(),
            quiet: false,
            min_reduction_frac: 0.2,
            screener_cache: std::sync::OnceLock::new(),
        }
    }
}

impl BenchConfig {
    /// Paper-scale configuration (`--full`).
    pub fn full(mut self) -> Self {
        self.sizes = vec![200, 400, 600, 800, 1000];
        self.image_scale = 4.0;
        self
    }

    /// The shared screener (compiled once; `None` = rust default).
    pub fn screener(&self) -> Option<std::sync::Arc<dyn crate::screening::Screener>> {
        self.screener_cache
            .get_or_init(|| match self.backend.screener() {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[bench] backend unavailable ({e:#}); using rust rules");
                    None
                }
            })
            .clone()
    }

    /// Pre-compile the PJRT executables for the buckets the given problem
    /// sizes will hit, so compile time never lands inside a measured run.
    pub fn warmup(&self, sizes: &[usize]) {
        let Some(screener) = self.screener() else { return };
        for &p in sizes {
            if p < 2 {
                continue;
            }
            let w = vec![0.5; p];
            let inputs = crate::screening::ScreenInputs {
                w: &w,
                gap: 1.0,
                f_v: -0.5 * p as f64,
                f_c: 0.0,
            };
            let _ = screener.screen(&inputs, RuleSet::all());
        }
    }

    fn options(&self, rules: RuleSet) -> Result<IaesOptions> {
        Ok(IaesOptions {
            eps: self.eps,
            rho: self.rho,
            rules,
            solver: solver_choice(&self.solver)?,
            max_iters: self.max_iters,
            screener: self.screener(),
            record_history: true,
            min_reduction_frac: self.min_reduction_frac,
            ..Default::default()
        })
    }

    fn log(&self, msg: &str) {
        if !self.quiet {
            eprintln!("[bench] {msg}");
        }
    }
}

impl std::fmt::Debug for BenchConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BenchConfig")
            .field("sizes", &self.sizes)
            .field("image_scale", &self.image_scale)
            .field("eps", &self.eps)
            .field("rho", &self.rho)
            .field("seed", &self.seed)
            .field("out_dir", &self.out_dir)
            .field("backend", &self.backend)
            .field("use_mi", &self.use_mi)
            .field("solver", &self.solver)
            .field("min_reduction_frac", &self.min_reduction_frac)
            .finish()
    }
}

/// One measured variant run.
#[derive(Clone, Debug)]
pub struct VariantRun {
    /// Wall time of the full solve.
    pub wall: Duration,
    /// Engine report.
    pub report: IaesReport,
}

/// Run one (workload, rules) variant.
pub fn run_variant(
    workload: &WorkloadSpec,
    rules: RuleSet,
    cfg: &BenchConfig,
) -> Result<VariantRun> {
    let job = JobSpec {
        name: workload.label(),
        workload: workload.clone(),
        opts: cfg.options(rules)?,
        decompose: None,
    };
    let res = job.run()?;
    Ok(VariantRun { wall: res.wall, report: res.report })
}

/// Run one (workload, rules) variant through the decomposable block
/// solver with `threads` workers.
pub fn run_variant_decomposed(
    workload: &WorkloadSpec,
    rules: RuleSet,
    cfg: &BenchConfig,
    threads: usize,
) -> Result<VariantRun> {
    let job = JobSpec {
        name: format!("{}+dec(t={threads})", workload.label()),
        workload: workload.clone(),
        opts: cfg.options(rules)?,
        decompose: Some(DecomposeOptions { threads, ..Default::default() }),
    };
    let res = job.run()?;
    Ok(VariantRun { wall: res.wall, report: res.report })
}

fn speedup(base: Duration, other: Duration) -> f64 {
    base.as_secs_f64() / other.as_secs_f64().max(1e-12)
}

fn secs(d: Duration) -> f64 {
    d.as_secs_f64()
}

/// Check variant minima agree (screening must be lossless).
fn check_consistent(label: &str, base: &IaesReport, variants: &[(&str, &IaesReport)]) {
    for (name, rep) in variants {
        let tol = 1e-4 * (1.0 + base.minimum.abs());
        if (rep.minimum - base.minimum).abs() > tol {
            eprintln!(
                "[bench] WARNING {label}: {name} minimum {} differs from baseline {}",
                rep.minimum, base.minimum
            );
        }
    }
}

/// **Table 1** — running time for SFM on two-moons: MinNorm alone vs
/// AES+ / IES+ / IAES+MinNorm, with per-variant screening overhead and
/// speedup columns, one row per `p`.
pub fn table1(cfg: &BenchConfig) -> Result<Table> {
    let mut table = Table::new(&[
        "p",
        "MinNorm",
        "AES",
        "AES+MN",
        "AES spdup",
        "IES",
        "IES+MN",
        "IES spdup",
        "IAES",
        "IAES+MN",
        "IAES spdup",
    ]);
    cfg.warmup(&cfg.sizes);
    for &p in &cfg.sizes {
        let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
        cfg.log(&format!("table1: p = {p} baseline"));
        let base = run_variant(&wl, RuleSet::none(), cfg)?;
        cfg.log(&format!("table1: p = {p} AES"));
        let aes = run_variant(&wl, RuleSet::aes_only(), cfg)?;
        cfg.log(&format!("table1: p = {p} IES"));
        let ies = run_variant(&wl, RuleSet::ies_only(), cfg)?;
        cfg.log(&format!("table1: p = {p} IAES"));
        let iaes = run_variant(&wl, RuleSet::all(), cfg)?;
        check_consistent(
            &format!("two-moons p={p}"),
            &base.report,
            &[("AES", &aes.report), ("IES", &ies.report), ("IAES", &iaes.report)],
        );
        table.push_row(vec![
            p.to_string(),
            fnum(secs(base.wall)),
            fnum(secs(aes.report.screen_time)),
            fnum(secs(aes.wall)),
            fnum(speedup(base.wall, aes.wall)),
            fnum(secs(ies.report.screen_time)),
            fnum(secs(ies.wall)),
            fnum(speedup(base.wall, ies.wall)),
            fnum(secs(iaes.report.screen_time)),
            fnum(secs(iaes.wall)),
            fnum(speedup(base.wall, iaes.wall)),
        ]);
    }
    table.write_csv(cfg.out_dir.join("table1.csv"))?;
    Ok(table)
}

/// **Table 2 + Table 3** — image-segmentation statistics and running
/// times. Returns `(table2, table3)`.
pub fn table3(cfg: &BenchConfig) -> Result<(Table, Table)> {
    let suite = benchmark_suite(cfg.image_scale);
    let mut t2 = Table::new(&["image", "#pixels", "#edges"]);
    for img in &suite {
        t2.push_row(vec![
            img.name.clone(),
            img.num_pixels().to_string(),
            img.num_edges().to_string(),
        ]);
    }
    t2.write_csv(cfg.out_dir.join("table2.csv"))?;
    cfg.warmup(&suite.iter().map(|i| i.num_pixels()).collect::<Vec<_>>());

    let mut t3 = Table::new(&[
        "image",
        "MinNorm",
        "AES",
        "AES+MN",
        "AES spdup",
        "IES",
        "IES+MN",
        "IES spdup",
        "IAES",
        "IAES+MN",
        "IAES spdup",
    ]);
    for (i, img) in suite.iter().enumerate() {
        let wl = WorkloadSpec::Image { index: i, scale: cfg.image_scale };
        cfg.log(&format!("table3: {} baseline", img.name));
        let base = run_variant(&wl, RuleSet::none(), cfg)?;
        cfg.log(&format!("table3: {} AES", img.name));
        let aes = run_variant(&wl, RuleSet::aes_only(), cfg)?;
        cfg.log(&format!("table3: {} IES", img.name));
        let ies = run_variant(&wl, RuleSet::ies_only(), cfg)?;
        cfg.log(&format!("table3: {} IAES", img.name));
        let iaes = run_variant(&wl, RuleSet::all(), cfg)?;
        check_consistent(
            &img.name,
            &base.report,
            &[("AES", &aes.report), ("IES", &ies.report), ("IAES", &iaes.report)],
        );
        t3.push_row(vec![
            img.name.clone(),
            fnum(secs(base.wall)),
            fnum(secs(aes.report.screen_time)),
            fnum(secs(aes.wall)),
            fnum(speedup(base.wall, aes.wall)),
            fnum(secs(ies.report.screen_time)),
            fnum(secs(ies.wall)),
            fnum(speedup(base.wall, ies.wall)),
            fnum(secs(iaes.report.screen_time)),
            fnum(secs(iaes.wall)),
            fnum(speedup(base.wall, iaes.wall)),
        ]);
    }
    t3.write_csv(cfg.out_dir.join("table3.csv"))?;
    Ok((t2, t3))
}

/// Rejection-ratio curve of one report: `(iter, (m_i+n_i)/p)` rows.
pub fn rejection_curve(report: &IaesReport, p: usize) -> Vec<(usize, f64)> {
    report
        .history
        .iter()
        .map(|rec| (rec.iter, (rec.active + rec.inactive) as f64 / p as f64))
        .collect()
}

/// **Figure 2** — rejection ratios over iterations on two-moons, one CSV
/// per size. Returns a summary table (final ratio + iterations).
pub fn fig2(cfg: &BenchConfig) -> Result<Table> {
    let mut table = Table::new(&["p", "iters", "final ratio", "triggers"]);
    cfg.warmup(&cfg.sizes);
    for &p in &cfg.sizes {
        let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
        cfg.log(&format!("fig2: p = {p}"));
        let run = run_variant(&wl, RuleSet::all(), cfg)?;
        let curve = rejection_curve(&run.report, p);
        write_csv_rows(
            cfg.out_dir.join(format!("fig2_p{p}.csv")),
            "iter,rejection_ratio",
            curve.iter().map(|(i, r)| format!("{i},{r}")),
        )?;
        let final_ratio = curve.last().map(|&(_, r)| r).unwrap_or(0.0);
        table.push_row(vec![
            p.to_string(),
            run.report.iters.to_string(),
            fnum(final_ratio),
            run.report.triggers.len().to_string(),
        ]);
    }
    table.write_csv(cfg.out_dir.join("fig2_summary.csv"))?;
    Ok(table)
}

/// **Figure 3** — visualization of the screening process on two-moons:
/// point coordinates + certification status after each trigger.
/// Writes `fig3_step{k}.csv` with columns `x,y,status` where status ∈
/// {active, inactive, unknown}. Returns a per-step summary table.
pub fn fig3(cfg: &BenchConfig, p: usize) -> Result<Table> {
    let tm = TwoMoons::generate(TwoMoonsParams { p, seed: cfg.seed, ..Default::default() });
    let f = tm.knn_cut(10, 1.0);
    let opts = cfg.options(RuleSet::all())?;
    let report = crate::screening::iaes::solve_sfm_with_screening(&f, &opts)?;

    // Status evolves trigger by trigger.
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Unknown,
        Active,
        Inactive,
    }
    let mut status = vec![St::Unknown; p];
    let mut table = Table::new(&["step", "iter", "active", "inactive", "unknown"]);
    let points = tm.points.clone();
    let mut emit = |step: usize, iter: usize, status: &[St]| -> Result<()> {
        write_csv_rows(
            cfg.out_dir.join(format!("fig3_step{step}.csv")),
            "x,y,status",
            (0..p).map(|i| {
                let s = match status[i] {
                    St::Unknown => "unknown",
                    St::Active => "active",
                    St::Inactive => "inactive",
                };
                format!("{},{},{}", tm.points[i][0], tm.points[i][1], s)
            }),
        )?;
        // PPM panel (the paper's Figure 3 is exactly this scatter).
        let st: Vec<crate::coordinator::render::PointStatus> = status
            .iter()
            .map(|s| match s {
                St::Active => crate::coordinator::render::PointStatus::Active,
                St::Inactive => crate::coordinator::render::PointStatus::Inactive,
                St::Unknown => crate::coordinator::render::PointStatus::Unknown,
            })
            .collect();
        crate::coordinator::render::scatter(&points, &st, 480)
            .write_ppm(cfg.out_dir.join(format!("fig3_step{step}.ppm")))?;
        let a = status.iter().filter(|&&s| s == St::Active).count();
        let n = status.iter().filter(|&&s| s == St::Inactive).count();
        table.push_row(vec![
            step.to_string(),
            iter.to_string(),
            a.to_string(),
            n.to_string(),
            (p - a - n).to_string(),
        ]);
        Ok(())
    };
    emit(0, 0, &status)?;
    for (step, trig) in report.triggers.iter().enumerate() {
        for &i in &trig.new_active_ids {
            status[i] = St::Active;
        }
        for &i in &trig.new_inactive_ids {
            status[i] = St::Inactive;
        }
        emit(step + 1, trig.iter, &status)?;
    }
    table.write_csv(cfg.out_dir.join("fig3_summary.csv"))?;
    Ok(table)
}

/// **Figure 4** — rejection ratios over iterations on the five images.
pub fn fig4(cfg: &BenchConfig) -> Result<Table> {
    let suite = benchmark_suite(cfg.image_scale);
    let mut table = Table::new(&["image", "p", "iters", "final ratio", "triggers"]);
    for (i, img) in suite.iter().enumerate() {
        let p = img.num_pixels();
        let wl = WorkloadSpec::Image { index: i, scale: cfg.image_scale };
        cfg.log(&format!("fig4: {}", img.name));
        let run = run_variant(&wl, RuleSet::all(), cfg)?;
        let curve = rejection_curve(&run.report, p);
        write_csv_rows(
            cfg.out_dir.join(format!("fig4_{}.csv", img.name)),
            "iter,rejection_ratio",
            curve.iter().map(|(it, r)| format!("{it},{r}")),
        )?;
        let final_ratio = curve.last().map(|&(_, r)| r).unwrap_or(0.0);
        table.push_row(vec![
            img.name.clone(),
            p.to_string(),
            run.report.iters.to_string(),
            fnum(final_ratio),
            run.report.triggers.len().to_string(),
        ]);
    }
    table.write_csv(cfg.out_dir.join("fig4_summary.csv"))?;
    Ok(table)
}

/// **Decompose** — monolithic vs block-parallel prox solves on the two
/// workload families, one row per two-moons size plus one per image,
/// with a thread-scaling column per entry in `threads`. The minima are
/// cross-checked (screening safety is solver-independent).
pub fn decompose_bench(cfg: &BenchConfig, threads: &[usize]) -> Result<Table> {
    let mut header: Vec<String> = vec!["workload".into(), "p".into(), "mono".into()];
    for &t in threads {
        header.push(format!("dec t={t}"));
        header.push(format!("spdup t={t}"));
    }
    let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&cols);
    cfg.warmup(&cfg.sizes);
    let mut workloads: Vec<(WorkloadSpec, usize)> = cfg
        .sizes
        .iter()
        .map(|&p| (WorkloadSpec::TwoMoons { p, use_mi: false, seed: cfg.seed }, p))
        .collect();
    let suite = benchmark_suite(cfg.image_scale);
    for (i, img) in suite.iter().enumerate() {
        workloads.push((
            WorkloadSpec::Image { index: i, scale: cfg.image_scale },
            img.num_pixels(),
        ));
    }
    for (wl, p) in &workloads {
        cfg.log(&format!("decompose: {} monolithic", wl.label()));
        let mono = run_variant(wl, RuleSet::all(), cfg)?;
        let mut row = vec![wl.label(), p.to_string(), fnum(secs(mono.wall))];
        for &t in threads {
            cfg.log(&format!("decompose: {} block t={t}", wl.label()));
            let dec = run_variant_decomposed(wl, RuleSet::all(), cfg, t)?;
            check_consistent(
                &format!("{} t={t}", wl.label()),
                &mono.report,
                &[("decomposed", &dec.report)],
            );
            row.push(fnum(secs(dec.wall)));
            row.push(fnum(speedup(mono.wall, dec.wall)));
        }
        table.push_row(row);
    }
    table.write_csv(cfg.out_dir.join("decompose.csv"))?;
    Ok(table)
}

/// **Ablation A1** — trigger frequency ρ (Remark 5).
pub fn ablation_rho(cfg: &BenchConfig, p: usize, rhos: &[f64]) -> Result<Table> {
    let mut table = Table::new(&["rho", "wall(s)", "screen(s)", "triggers", "iters"]);
    for &rho in rhos {
        let mut c = cfg.clone();
        c.rho = rho;
        let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
        cfg.log(&format!("ablation_rho: rho = {rho}"));
        let run = run_variant(&wl, RuleSet::all(), &c)?;
        table.push_row(vec![
            fnum(rho),
            fnum(secs(run.wall)),
            fnum(secs(run.report.screen_time)),
            run.report.triggers.len().to_string(),
            run.report.iters.to_string(),
        ]);
    }
    table.write_csv(cfg.out_dir.join("ablation_rho.csv"))?;
    Ok(table)
}

/// **Ablation A2** — contribution of the two rule pairs.
pub fn ablation_rules(cfg: &BenchConfig, p: usize) -> Result<Table> {
    let mut table = Table::new(&["rules", "wall(s)", "final ratio", "iters"]);
    let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
    for (name, rules) in [
        ("none", RuleSet::none()),
        ("pair1 (B∩P)", RuleSet::pair1_only()),
        ("pair2 (B∩Ω)", RuleSet::pair2_only()),
        ("all", RuleSet::all()),
    ] {
        cfg.log(&format!("ablation_rules: {name}"));
        let run = run_variant(&wl, rules, cfg)?;
        let ratio = run.report.final_rejection_ratio(p);
        table.push_row(vec![
            name.to_string(),
            fnum(secs(run.wall)),
            fnum(ratio),
            run.report.iters.to_string(),
        ]);
    }
    table.write_csv(cfg.out_dir.join("ablation_rules.csv"))?;
    Ok(table)
}

/// **Ablation A3** — solver A choice (Remark 2).
pub fn ablation_solver(cfg: &BenchConfig, p: usize) -> Result<Table> {
    let mut table =
        Table::new(&["solver", "screening", "wall(s)", "iters", "final gap"]);
    let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
    for solver in ["minnorm", "fw"] {
        for (sname, rules) in [("off", RuleSet::none()), ("iaes", RuleSet::all())] {
            let mut c = cfg.clone();
            c.solver = solver.to_string();
            // Conditional gradient converges sublinearly to tight gaps;
            // cap the iteration budget and report the gap reached.
            c.max_iters = c.max_iters.min(20_000);
            cfg.log(&format!("ablation_solver: {solver}/{sname}"));
            let run = run_variant(&wl, rules, &c)?;
            table.push_row(vec![
                solver.to_string(),
                sname.to_string(),
                fnum(secs(run.wall)),
                run.report.iters.to_string(),
                format!("{:.2e}", run.report.final_gap),
            ]);
        }
    }
    table.write_csv(cfg.out_dir.join("ablation_solver.csv"))?;
    Ok(table)
}

/// Check that a submodular oracle's IAES minimum matches a screening-free
/// solve (used by the e2e example and the micro bench sanity block).
pub fn verify_lossless(f: &dyn Submodular, cfg: &BenchConfig) -> Result<(f64, f64)> {
    let opts_off = cfg.options(RuleSet::none())?;
    let opts_on = cfg.options(RuleSet::all())?;
    let t0 = Instant::now();
    let off = crate::screening::iaes::solve_sfm_with_screening(f, &opts_off)?;
    let _t_off = t0.elapsed();
    let on = crate::screening::iaes::solve_sfm_with_screening(f, &opts_on)?;
    Ok((off.minimum, on.minimum))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(clippy::field_reassign_with_default)]
    fn tiny_cfg(dir: &str) -> BenchConfig {
        let mut c = BenchConfig::default();
        c.sizes = vec![30, 40];
        c.eps = 1e-5;
        c.out_dir = std::env::temp_dir().join(dir);
        c.quiet = true;
        c.backend = BackendChoice::Rust;
        c
    }

    #[test]
    fn table1_smoke() {
        let cfg = tiny_cfg("sfm_t1");
        let t = table1(&cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert!(cfg.out_dir.join("table1.csv").is_file());
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn fig2_and_fig3_smoke() {
        let cfg = tiny_cfg("sfm_f23");
        let t = fig2(&cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
        let t3 = fig3(&cfg, 30).unwrap();
        assert!(!t3.rows.is_empty());
        assert!(cfg.out_dir.join("fig3_step0.csv").is_file());
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn ablations_smoke() {
        let cfg = tiny_cfg("sfm_abl");
        let t = ablation_rho(&cfg, 30, &[0.3, 0.7]).unwrap();
        assert_eq!(t.rows.len(), 2);
        let t = ablation_rules(&cfg, 30).unwrap();
        assert_eq!(t.rows.len(), 4);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn decompose_bench_smoke() {
        let mut cfg = tiny_cfg("sfm_dec");
        cfg.sizes = vec![30];
        cfg.image_scale = 0.12; // every scene clamps to 8x8 = 64 pixels
        let t = decompose_bench(&cfg, &[1]).unwrap();
        assert_eq!(t.rows.len(), 1 + 5, "one two-moons row + five images");
        assert!(cfg.out_dir.join("decompose.csv").is_file());
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }

    #[test]
    fn rejection_curve_monotone() {
        let cfg = tiny_cfg("sfm_rc");
        let wl = WorkloadSpec::TwoMoons { p: 40, use_mi: false, seed: 1 };
        let run = run_variant(&wl, RuleSet::all(), &cfg).unwrap();
        let curve = rejection_curve(&run.report, 40);
        let mut last = 0.0;
        for &(_, r) in &curve {
            assert!(r >= last - 1e-12);
            last = r;
        }
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
