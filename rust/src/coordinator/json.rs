//! Minimal JSON emission (no serde offline): enough to export reports and
//! bench results for downstream tooling, with correct string escaping and
//! float formatting.

use crate::screening::iaes::IaesReport;
use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    /// `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (finite f64; NaN/inf serialize as null per common practice).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to a compact string.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Export an [`IaesReport`] as JSON (history omitted unless `with_history`).
pub fn report_to_json(report: &IaesReport, with_history: bool) -> Json {
    let mut pairs = vec![
        ("minimum", Json::Num(report.minimum)),
        (
            "minimizer",
            Json::Arr(report.minimizer.iter().map(|&i| Json::Num(i as f64)).collect()),
        ),
        ("iters", Json::Num(report.iters as f64)),
        ("final_gap", Json::Num(report.final_gap)),
        ("screened_active", Json::Num(report.screened_active as f64)),
        ("screened_inactive", Json::Num(report.screened_inactive as f64)),
        ("emptied", Json::Bool(report.emptied)),
        ("solver_time_s", Json::Num(report.solver_time.as_secs_f64())),
        ("screen_time_s", Json::Num(report.screen_time.as_secs_f64())),
        (
            "triggers",
            Json::Arr(
                report
                    .triggers
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("iter", Json::Num(t.iter as f64)),
                            ("gap", Json::Num(t.gap)),
                            ("p_before", Json::Num(t.p_before as f64)),
                            ("new_active", Json::Num(t.new_active as f64)),
                            ("new_inactive", Json::Num(t.new_inactive as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if with_history {
        pairs.push((
            "history",
            Json::Arr(
                report
                    .history
                    .iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("iter", Json::Num(h.iter as f64)),
                            ("gap", Json::Num(h.gap)),
                            ("active", Json::Num(h.active as f64)),
                            ("inactive", Json::Num(h.inactive as f64)),
                            ("p_remaining", Json::Num(h.p_remaining as f64)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions};
    use crate::submodular::iwata::IwataFn;

    #[test]
    fn scalar_serialization() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Str("a\"b\n".into()).to_string(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nested_structure() {
        let j = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("name", Json::Str("t1".into())),
        ]);
        assert_eq!(j.to_string(), r#"{"xs":[1,2],"name":"t1"}"#);
    }

    #[test]
    fn report_roundtrip_shape() {
        let f = IwataFn::new(12);
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let j = report_to_json(&report, true).to_string();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"minimum\""));
        assert!(j.contains("\"history\""));
        // Balanced braces (cheap well-formedness check).
        let opens = j.matches('{').count();
        let closes = j.matches('}').count();
        assert_eq!(opens, closes);
    }
}
