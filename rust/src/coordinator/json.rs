//! Minimal JSON emission *and parsing* (no serde offline): enough to
//! export reports and bench results for downstream tooling — with correct
//! string escaping and float formatting — and to read `BENCH_*.json`
//! trajectories back for the regression comparator.

use crate::obs::trace::KIND_NAMES;
use crate::screening::iaes::IaesReport;
use anyhow::{bail, Result};
use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    /// `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (finite f64; NaN/inf serialize as null per common practice).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match; `None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number inside a `Num`. `Null` — which is how the emitter
    /// serializes NaN/inf — reads back as NaN so numeric fields
    /// round-trip without erroring; everything else is `None`.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// The string inside a `Str`, else `None`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The bool inside a `Bool`, else `None`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items of an `Arr`, else `None`.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse a JSON document (recursive descent over the subset this
    /// module emits: null/bool/number/string/array/object, `\uXXXX`
    /// escapes included). Rejects trailing garbage.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(value)
    }

    /// Serialize to a compact string.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected `{lit}` at byte {}", *pos);
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'n') => expect(b, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|()| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected `,` or `]` at byte {}", *pos),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let value = parse_value(b, pos)?;
                pairs.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => bail!("expected `,` or `}}` at byte {}", *pos),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        // `*pos` is at the `u`; the escape's backslash is
                        // one byte back (used in error messages).
                        let esc_at = *pos - 1;
                        let code = parse_hex4(b, *pos + 1)?;
                        match code {
                            0xD800..=0xDBFF => {
                                // High surrogate: only valid as the first
                                // half of a \uD8xx\uDCxx pair encoding one
                                // supplementary-plane scalar (JSON strings
                                // escape non-BMP characters this way).
                                if b.get(*pos + 5) != Some(&b'\\')
                                    || b.get(*pos + 6) != Some(&b'u')
                                {
                                    bail!(
                                        "lone high surrogate \\u{code:04X} at byte \
                                         {esc_at}: expected a low-surrogate \
                                         \\uDC00–\\uDFFF escape to follow"
                                    );
                                }
                                let lo = parse_hex4(b, *pos + 7)?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    bail!(
                                        "lone high surrogate \\u{code:04X} at byte \
                                         {esc_at}: \\u{lo:04X} is not a low \
                                         surrogate (\\uDC00–\\uDFFF)"
                                    );
                                }
                                let scalar =
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(scalar).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "bad surrogate pair at byte {esc_at}"
                                    )
                                })?);
                                *pos += 10;
                            }
                            0xDC00..=0xDFFF => bail!(
                                "lone low surrogate \\u{code:04X} at byte {esc_at}: a \
                                 low surrogate is only valid directly after a high \
                                 surrogate"
                            ),
                            c => {
                                out.push(char::from_u32(c).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "bad \\u escape \\u{c:04X} at byte {esc_at}"
                                    )
                                })?);
                                *pos += 4;
                            }
                        }
                    }
                    _ => bail!("bad escape at byte {}", *pos),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte sequences copied
                // verbatim).
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && b[*pos] & 0xC0 == 0x80 {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                );
            }
        }
    }
}

/// Four hex digits of a `\uXXXX` escape starting at byte `at`.
fn parse_hex4(b: &[u8], at: usize) -> Result<u32> {
    let hex = b
        .get(at..at + 4)
        .ok_or_else(|| anyhow::anyhow!("truncated \\u escape at byte {at}"))?;
    if !hex.iter().all(u8::is_ascii_hexdigit) {
        bail!("bad \\u escape at byte {at} (four hex digits required)");
    }
    let s = std::str::from_utf8(hex)
        .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {at} (non-ascii)"))?;
    u32::from_str_radix(s, 16)
        .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {at} (not hex)"))
}

/// Parse a number following the exact JSON grammar
/// (`-? (0 | [1-9][0-9]*) ('.' [0-9]+)? ([eE] [+-]? [0-9]+)?`) — no
/// leading `+`, no leading zeros, no bare `.5`/`1.` forms. The error for
/// a malformed token reports the whole number-ish byte run (`1.2.3`,
/// `01`, `+1`, …) instead of a misleading `f64::parse` failure on a
/// greedily gobbled span.
fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    // The maximal number-ish run, for error reporting only.
    let mut scan = start;
    while scan < b.len()
        && matches!(b[scan], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        scan += 1;
    }
    if scan == start {
        bail!("expected a value at byte {start}");
    }
    let token = std::str::from_utf8(&b[start..scan])
        .map_err(|_| anyhow::anyhow!("bad number at byte {start} (non-ascii)"))?;
    let mut i = start;
    if b.get(i) == Some(&b'-') {
        i += 1;
    }
    // Integer part: 0, or a nonzero digit followed by digits.
    match b.get(i) {
        Some(b'0') => i += 1,
        Some(b'1'..=b'9') => {
            i += 1;
            while matches!(b.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => bail!("bad number `{token}` at byte {start} (not a JSON number)"),
    }
    // Fraction: '.' followed by at least one digit.
    if b.get(i) == Some(&b'.') {
        i += 1;
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            bail!("bad number `{token}` at byte {start} (digits required after `.`)");
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    // Exponent: e/E, optional sign, at least one digit.
    if matches!(b.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            bail!("bad number `{token}` at byte {start} (digits required in exponent)");
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    // Anything number-ish left over means the token as a whole is not a
    // JSON number (`1.2.3`, `1e2e3`, `01`, `1..2`, …) — reject it here
    // with the full token instead of letting the top level report a
    // baffling "trailing characters".
    if i < scan {
        bail!("bad number `{token}` at byte {start} (not a JSON number)");
    }
    let text = std::str::from_utf8(&b[start..i])
        .map_err(|_| anyhow::anyhow!("bad number at byte {start} (non-ascii)"))?;
    let x: f64 = text
        .parse()
        .map_err(|_| anyhow::anyhow!("bad number `{text}` at byte {start}"))?;
    *pos = i;
    Ok(Json::Num(x))
}

/// Export an [`IaesReport`] as JSON (history omitted unless `with_history`).
pub fn report_to_json(report: &IaesReport, with_history: bool) -> Json {
    let mut pairs = vec![
        ("minimum", Json::Num(report.minimum)),
        (
            "minimizer",
            Json::Arr(report.minimizer.iter().map(|&i| Json::Num(i as f64)).collect()),
        ),
        ("iters", Json::Num(report.iters as f64)),
        ("final_gap", Json::Num(report.final_gap)),
        ("screened_active", Json::Num(report.screened_active as f64)),
        ("screened_inactive", Json::Num(report.screened_inactive as f64)),
        ("emptied", Json::Bool(report.emptied)),
        ("converged", Json::Bool(report.converged)),
        (
            "cancel_reason",
            match report.cancel_reason {
                Some(r) => Json::Str(r.as_str().to_string()),
                None => Json::Null,
            },
        ),
        (
            "block_threads",
            match report.block_threads {
                Some(t) => Json::Num(t as f64),
                None => Json::Null,
            },
        ),
        (
            "greedy_threads",
            match report.greedy_threads {
                Some(t) => Json::Num(t as f64),
                None => Json::Null,
            },
        ),
        ("solver_time_s", Json::Num(report.solver_time.as_secs_f64())),
        ("screen_time_s", Json::Num(report.screen_time.as_secs_f64())),
        (
            // Boundary-sampled telemetry totals (null unless the solve
            // ran with a trace sink attached). Nanos become seconds here
            // — the JSON layer is float-based end to end.
            "trace",
            match &report.trace {
                Some(t) => {
                    let s = |ns: u64| Json::Num(ns as f64 * 1e-9);
                    Json::obj(vec![
                        ("events", Json::Num(t.events as f64)),
                        ("dropped", Json::Num(t.dropped as f64)),
                        ("screens", Json::Num(t.screens as f64)),
                        ("contractions", Json::Num(t.contractions as f64)),
                        ("greedy_s", s(t.greedy_ns)),
                        ("prox_s", s(t.prox_ns)),
                        ("screen_s", s(t.screen_ns)),
                        ("contract_s", s(t.contract_ns)),
                        (
                            "kind_s",
                            Json::obj(
                                KIND_NAMES
                                    .iter()
                                    .zip(&t.kind_ns)
                                    .map(|(&k, &ns)| (k, s(ns)))
                                    .collect(),
                            ),
                        ),
                        ("pool_dispatches", Json::Num(t.pool_dispatches as f64)),
                    ])
                }
                None => Json::Null,
            },
        ),
        (
            "triggers",
            Json::Arr(
                report
                    .triggers
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("iter", Json::Num(t.iter as f64)),
                            ("gap", Json::Num(t.gap)),
                            ("p_before", Json::Num(t.p_before as f64)),
                            ("new_active", Json::Num(t.new_active as f64)),
                            ("new_inactive", Json::Num(t.new_inactive as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if with_history {
        pairs.push((
            "history",
            Json::Arr(
                report
                    .history
                    .iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("iter", Json::Num(h.iter as f64)),
                            ("gap", Json::Num(h.gap)),
                            ("active", Json::Num(h.active as f64)),
                            ("inactive", Json::Num(h.inactive as f64)),
                            ("p_remaining", Json::Num(h.p_remaining as f64)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions};
    use crate::submodular::iwata::IwataFn;

    #[test]
    fn scalar_serialization() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Str("a\"b\n".into()).to_string(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nested_structure() {
        let j = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("name", Json::Str("t1".into())),
        ]);
        assert_eq!(j.to_string(), r#"{"xs":[1,2],"name":"t1"}"#);
    }

    #[test]
    fn report_roundtrip_shape() {
        let f = IwataFn::new(12);
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let j = report_to_json(&report, true).to_string();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"minimum\""));
        assert!(j.contains("\"history\""));
        assert!(j.contains("\"converged\":true"));
        // Balanced braces (cheap well-formedness check).
        let opens = j.matches('{').count();
        let closes = j.matches('}').count();
        assert_eq!(opens, closes);
        // And the emitted document parses back into the same shape.
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("converged").and_then(Json::as_bool), Some(true));
        assert!(parsed.get("minimum").and_then(Json::as_num).is_some());
        assert!(parsed.get("history").and_then(Json::as_array).is_some());
        // Monolithic sequential solves report null worker counts…
        assert!(matches!(parsed.get("block_threads"), Some(Json::Null)));
        assert!(matches!(parsed.get("greedy_threads"), Some(Json::Null)));
        // …and an uncancelled run reports a null cancel reason.
        assert!(matches!(parsed.get("cancel_reason"), Some(Json::Null)));
    }

    #[test]
    fn cancelled_report_carries_the_reason() {
        use crate::runtime::cancel::CancelToken;
        let f = IwataFn::new(10);
        let opts = IaesOptions {
            cancel: Some(CancelToken::with_deadline(std::time::Duration::ZERO)),
            ..Default::default()
        };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        let parsed = Json::parse(&report_to_json(&report, false).to_string()).unwrap();
        assert_eq!(
            parsed.get("cancel_reason").and_then(Json::as_str),
            Some("deadline")
        );
        assert_eq!(parsed.get("converged").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn traced_report_emits_summary_and_untraced_emits_null() {
        use crate::obs::trace::TraceSink;
        let f = IwataFn::new(16);
        let plain = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let parsed = Json::parse(&report_to_json(&plain, false).to_string()).unwrap();
        assert!(matches!(parsed.get("trace"), Some(Json::Null)));

        let opts = IaesOptions { trace: Some(TraceSink::new()), ..Default::default() };
        let traced = solve_sfm_with_screening(&f, &opts).unwrap();
        let parsed = Json::parse(&report_to_json(&traced, false).to_string()).unwrap();
        let t = parsed.get("trace").unwrap();
        // Every major iteration records exactly one boundary event.
        assert_eq!(
            t.get("events").and_then(Json::as_num),
            Some(traced.iters as f64)
        );
        assert_eq!(t.get("dropped").and_then(Json::as_num), Some(0.0));
        assert_eq!(
            t.get("contractions").and_then(Json::as_num),
            Some(traced.trace.unwrap().contractions as f64)
        );
        // Phase totals are seconds and the kind split names every slot.
        assert!(t.get("greedy_s").and_then(Json::as_num).unwrap() >= 0.0);
        for kind in crate::obs::trace::KIND_NAMES {
            assert!(t.get("kind_s").unwrap().get(kind).is_some(), "kind_s.{kind}");
        }
    }

    #[test]
    fn pooled_monolithic_report_carries_greedy_threads() {
        use crate::rng::Pcg64;
        let p = 140; // above the pooled kernel-cut gate
        let mut rng = Pcg64::seeded(6);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 0.2);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let f = crate::submodular::kernel_cut::KernelCutFn::new(
            p,
            k,
            rng.uniform_vec(p, -2.0, 2.0),
        );
        let opts = IaesOptions { threads: 2, ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        let parsed = Json::parse(&report_to_json(&report, false).to_string()).unwrap();
        // …while pooled monolithic runs record the resolved count.
        assert_eq!(parsed.get("greedy_threads").and_then(Json::as_num), Some(2.0));
        assert!(matches!(parsed.get("block_threads"), Some(Json::Null)));
    }

    #[test]
    fn decomposed_report_carries_block_threads() {
        use crate::decompose::builders::star_components;
        use crate::decompose::{solve_decomposed, DecomposeOptions};
        use crate::rng::Pcg64;
        let p = 8;
        let mut rng = Pcg64::seeded(5);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let dec = star_components(p, |i, j| k[i * p + j], rng.uniform_vec(p, -1.0, 1.0));
        let report = solve_decomposed(
            &dec,
            &IaesOptions::default(),
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        let j = report_to_json(&report, false).to_string();
        let parsed = Json::parse(&j).unwrap();
        // …while --decompose runs record the resolved parallelism.
        assert_eq!(parsed.get("block_threads").and_then(Json::as_num), Some(2.0));
    }

    #[test]
    fn parse_scalars_and_structure() {
        assert!(matches!(Json::parse("null").unwrap(), Json::Null));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("-3.5e2").unwrap().as_num(), Some(-350.0));
        assert_eq!(
            Json::parse("\"a\\\"b\\n\\u0041\"").unwrap().as_str(),
            Some("a\"b\nA")
        );
        let v = Json::parse(r#"{ "xs": [1, 2.5, null], "name": "t1" }"#).unwrap();
        let xs = v.get("xs").and_then(Json::as_array).unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1].as_num(), Some(2.5));
        assert!(matches!(xs[2], Json::Null));
        // Null (serialized NaN/inf) reads back as NaN, not an error.
        assert!(xs[2].as_num().is_some_and(f64::is_nan));
        assert_eq!(v.get("name").and_then(Json::as_str), Some("t1"));
        assert!(v.get("missing").is_none());
        // Empty containers and unicode pass-through.
        assert!(Json::parse("[]").unwrap().as_array().unwrap().is_empty());
        assert!(matches!(Json::parse("{}").unwrap(), Json::Obj(ref p) if p.is_empty()));
        assert_eq!(Json::parse("\"é←\"").unwrap().as_str(), Some("é←"));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "tru", "\"open", "{\"a\" 1}", "1 2", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn surrogate_pairs_combine_into_one_scalar() {
        // U+1F600 (grinning face) escaped as its surrogate pair.
        assert_eq!(
            Json::parse(r#""\uD83D\uDE00""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
        // Mixed with BMP escapes and raw UTF-8 on both sides.
        assert_eq!(
            Json::parse(r#""a\u00e9\uD83D\uDE00\u00E9A""#).unwrap().as_str(),
            Some("a\u{e9}\u{1F600}\u{e9}A")
        );
        // The extremes of the supplementary planes: U+10000 and U+10FFFF.
        assert_eq!(
            Json::parse(r#""\uD800\uDC00""#).unwrap().as_str(),
            Some("\u{10000}")
        );
        assert_eq!(
            Json::parse(r#""\uDBFF\uDFFF""#).unwrap().as_str(),
            Some("\u{10FFFF}")
        );
        // Raw (unescaped) non-BMP passes through unchanged.
        assert_eq!(Json::parse("\"\u{1F600}\"").unwrap().as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn lone_surrogates_are_rejected_with_a_clear_message() {
        for (doc, needle) in [
            (r#""\uD800""#, "lone high surrogate"),
            (r#""\uD83Dx""#, "lone high surrogate"),
            (r#""\uD83DA""#, "lone high surrogate"),
            (r#""\uD83D\u0041""#, "not a low surrogate"),
            (r#""\uD83D\uD83D""#, "not a low surrogate"),
            (r#""\uDC00""#, "lone low surrogate"),
            (r#""\uDE00abc""#, "lone low surrogate"),
        ] {
            let err = Json::parse(doc).unwrap_err().to_string();
            assert!(err.contains(needle), "`{doc}`: got `{err}`, wanted `{needle}`");
        }
        // Truncated and non-hex escapes still fail cleanly.
        assert!(Json::parse(r#""\uD83D\u12""#).is_err());
        assert!(Json::parse(r#""\uZZZZ""#).is_err());
    }

    #[test]
    fn number_grammar_accepts_exactly_json_numbers() {
        for (doc, want) in [
            ("0", 0.0),
            ("-0", 0.0),
            ("10", 10.0),
            ("-3.25", -3.25),
            ("0.5", 0.5),
            ("1e6", 1e6),
            ("2E-3", 2e-3),
            ("-1.5e+2", -150.0),
            ("9007199254740993", 9007199254740992.0), // f64 rounding, not an error
        ] {
            let got = Json::parse(doc).unwrap().as_num().unwrap();
            assert_eq!(got, want, "doc `{doc}`");
        }
        for bad in [
            "+1", "++1", "--1", "-", ".5", "1.", "1.e3", "01", "-01", "1e", "1e+",
            "1.2.3", "1e2e3", "1..2", "1.-2", "+",
        ] {
            let err = Json::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("number") || err.contains("expected a value"),
                "`{bad}`: unhelpful error `{err}`"
            );
        }
        // The offending token is named in full (no greedy-gobble confusion).
        let err = Json::parse("[1.2.3]").unwrap_err().to_string();
        assert!(err.contains("1.2.3"), "error should name the token: {err}");
        let err = Json::parse("[+1]").unwrap_err().to_string();
        assert!(err.contains("+1"), "error should name the token: {err}");
    }

    #[test]
    fn emit_parse_roundtrip_is_stable() {
        let j = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(0.25)])),
            ("s", Json::Str("q\"\\\n".into())),
            ("flag", Json::Bool(false)),
            ("none", Json::Null),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text);
    }

    /// Random nested documents — including non-BMP strings and control
    /// characters — must survive emit → parse → emit byte-identically.
    #[test]
    fn random_documents_roundtrip_byte_identically() {
        use crate::rng::Pcg64;
        fn random_string(rng: &mut Pcg64) -> String {
            let alphabet: Vec<char> = vec![
                'a', 'Z', '9', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{0001}',
                '\u{001F}', 'é', '←', '日', '😀', '\u{10FFFF}', '\u{1F4A9}',
            ];
            let n = rng.below(12);
            (0..n).map(|_| alphabet[rng.below(alphabet.len())]).collect()
        }
        fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => {
                    // Mix of integers, dyadic fractions (exact in f64),
                    // and free normals.
                    match rng.below(3) {
                        0 => Json::Num((rng.below(2001) as f64) - 1000.0),
                        1 => Json::Num((rng.below(64) as f64) / 16.0),
                        _ => Json::Num(rng.normal()),
                    }
                }
                3 => Json::Str(random_string(rng)),
                4 => Json::Arr(
                    (0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect(),
                ),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}-{}", random_string(rng)), random_json(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Pcg64::seeded(20260731);
        for case in 0..300 {
            let doc = random_json(&mut rng, 3);
            let text = doc.to_string();
            let back = Json::parse(&text)
                .unwrap_or_else(|e| panic!("case {case}: `{text}` failed: {e}"));
            assert_eq!(back.to_string(), text, "case {case} not byte-stable");
        }
    }
}
