//! Report writers: CSV files under an output directory plus aligned text
//! tables for the terminal (mirroring the paper's table layout).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// A rectangular table with named columns.
#[derive(Clone, Debug)]
pub struct Table {
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of stringified cells (same arity as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Write as CSV (quoting cells containing separators).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "{}", csv_line(&self.headers))?;
        for row in &self.rows {
            writeln!(f, "{}", csv_line(row))?;
        }
        Ok(())
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

fn csv_line(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Write raw CSV rows (for curve data that isn't naturally a `Table`).
pub fn write_csv_rows(
    path: impl AsRef<Path>,
    header: &str,
    rows: impl IntoIterator<Item = String>,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

/// Resolve (and create) the output directory for bench artifacts.
pub fn out_dir(base: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(base)
        .with_context(|| format!("creating {}", base.display()))?;
    Ok(base.to_path_buf())
}

/// Format a float with sensible precision for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(&["p", "time", "speedup"]);
        t.push_row(vec!["200".into(), "1.25".into(), "6.8".into()]);
        t.push_row(vec!["400".into(), "10.1".into(), "10.0".into()]);
        let rendered = t.render();
        assert!(rendered.contains("speedup"));
        assert!(rendered.lines().count() == 4);

        let dir = std::env::temp_dir().join("sfm_screen_test_report");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().next().unwrap(), "p,time,speedup");
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_quoting() {
        assert_eq!(
            csv_line(&["a,b".into(), "plain".into(), "q\"q".into()]),
            "\"a,b\",plain,\"q\"\"q\""
        );
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec!["1".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(123.456), "123.5");
        assert_eq!(fnum(2.5), "2.50");
        assert_eq!(fnum(0.01234), "0.0123");
    }
}
