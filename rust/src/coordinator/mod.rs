//! Experiment coordinator — the launcher a downstream user actually runs.
//!
//! * [`jobs`] — declarative experiment specs (workload × solver × rules ×
//!   backend) and their results.
//! * [`runner`] — a work-stealing thread pool for independent jobs.
//! * [`metrics`] — wall-clock measurement utilities (stopwatch, robust
//!   summaries) shared by the bench harness.
//! * [`report`] — CSV and aligned-table writers for `bench_out/`.
//! * [`experiments`] — the paper's evaluation: Table 1, Table 3,
//!   Figures 2–4, and the DESIGN.md ablations, each as a reusable function
//!   called by both the CLI and `cargo bench`.
//! * [`serve`] — the fault-isolated resident solve service
//!   (`sfm-screen serve`): bounded admission, per-job deadlines and
//!   cancellation, panic containment, and an instance cache.

pub mod experiments;
pub mod jobs;
pub mod json;
pub mod metrics;
pub mod render;
pub mod report;
pub mod runner;
pub mod serve;

pub use experiments::BenchConfig;
pub use jobs::{BackendChoice, JobResult, JobSpec, WorkloadSpec};
