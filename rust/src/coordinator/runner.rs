//! Work-stealing job runner over OS threads.
//!
//! No tokio in the offline environment — and none needed: jobs are
//! CPU-bound solves. `run_parallel` executes independent jobs on a scoped
//! thread pool with an atomic work index; results come back in input
//! order. Timing-sensitive benchmarks use `threads = 1` for fairness.

use super::jobs::{JobResult, JobSpec};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run all jobs with up to `threads` workers; results in input order.
/// The first job error aborts the batch.
pub fn run_parallel(jobs: &[JobSpec], threads: usize) -> Result<Vec<JobResult>> {
    let threads = threads.max(1).min(jobs.len().max(1));
    if threads == 1 {
        return jobs.iter().map(|j| j.run()).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<JobResult>>>> =
        (0..jobs.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let out = jobs[i].run();
                *results[i].lock().expect("runner poisoned") = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|slot| slot.into_inner().expect("runner poisoned").expect("job not run"))
        .collect()
}

/// Run jobs sequentially with a progress callback after each.
pub fn run_with_progress(
    jobs: &[JobSpec],
    mut progress: impl FnMut(usize, &JobResult),
) -> Result<Vec<JobResult>> {
    let mut out = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.iter().enumerate() {
        let res = job.run()?;
        progress(i, &res);
        out.push(res);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::WorkloadSpec;
    use crate::screening::iaes::IaesOptions;

    fn jobs(n: usize) -> Vec<JobSpec> {
        (0..n)
            .map(|i| JobSpec {
                name: format!("iwata-{i}"),
                workload: WorkloadSpec::Iwata { p: 15 + i },
                opts: IaesOptions::default(),
            })
            .collect()
    }

    #[test]
    fn parallel_matches_sequential() {
        let js = jobs(6);
        let seq = run_parallel(&js, 1).unwrap();
        let par = run_parallel(&js, 4).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.name, b.name);
            assert!((a.report.minimum - b.report.minimum).abs() < 1e-9);
            assert_eq!(a.report.minimizer, b.report.minimizer);
        }
    }

    #[test]
    fn progress_callback_fires() {
        let js = jobs(3);
        let mut seen = Vec::new();
        run_with_progress(&js, |i, r| seen.push((i, r.name.clone()))).unwrap();
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[2].0, 2);
    }

    #[test]
    fn empty_job_list() {
        assert!(run_parallel(&[], 4).unwrap().is_empty());
    }
}
