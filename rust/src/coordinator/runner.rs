//! Work-stealing job runner over OS threads.
//!
//! No tokio in the offline environment — and none needed: jobs are
//! CPU-bound solves. `run_parallel` executes independent jobs on a scoped
//! thread pool with an atomic work index; results come back in input
//! order. Timing-sensitive benchmarks use `threads = 1` for fairness.

use super::jobs::{JobResult, JobSpec};
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Render a `catch_unwind` payload (panics carry `&str` or `String`
/// messages in practice; anything else is opaque). Shared with the
/// serve-mode job containment boundary.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one job, converting a panic into an error naming the job.
fn run_caught(i: usize, job: &JobSpec) -> Result<JobResult> {
    run_caught_with(i, job, || job.run())
}

/// Panic-catching wrapper around a job execution closure (split from
/// [`run_caught`] so the unwind path is unit-testable).
fn run_caught_with(
    i: usize,
    job: &JobSpec,
    run: impl FnOnce() -> Result<JobResult>,
) -> Result<JobResult> {
    match catch_unwind(AssertUnwindSafe(run)) {
        Ok(res) => res,
        Err(payload) => Err(anyhow!(
            "job {i} (`{}`, {}) panicked: {}",
            job.name,
            job.workload.label(),
            panic_message(payload.as_ref())
        )),
    }
}

/// Run all jobs with up to `threads` workers; results in input order.
/// The first job error aborts the batch. A job that panics is caught and
/// surfaced as an error naming the failing job index and spec — it never
/// takes down the worker (or the collector) with an opaque unwind.
pub fn run_parallel(jobs: &[JobSpec], threads: usize) -> Result<Vec<JobResult>> {
    let threads = threads.max(1).min(jobs.len().max(1));
    if threads == 1 {
        return jobs.iter().enumerate().map(|(i, j)| run_caught(i, j)).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<JobResult>>>> =
        (0..jobs.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let out = run_caught(i, &jobs[i]);
                // Poison recovery: the slot holds one scalar Option and
                // writers never panic mid-store, so adopting a poisoned
                // lock can only observe a fully written (or empty) slot.
                *results[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(out);
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .unwrap_or_else(|| {
                    Err(anyhow!(
                        "job {i} (`{}`, {}) was never executed (worker lost)",
                        jobs[i].name,
                        jobs[i].workload.label()
                    ))
                })
        })
        .collect()
}

/// Run jobs sequentially with a progress callback after each.
pub fn run_with_progress(
    jobs: &[JobSpec],
    mut progress: impl FnMut(usize, &JobResult),
) -> Result<Vec<JobResult>> {
    let mut out = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.iter().enumerate() {
        let res = job.run()?;
        progress(i, &res);
        out.push(res);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::WorkloadSpec;
    use crate::screening::iaes::IaesOptions;

    fn jobs(n: usize) -> Vec<JobSpec> {
        (0..n)
            .map(|i| JobSpec {
                name: format!("iwata-{i}"),
                workload: WorkloadSpec::Iwata { p: 15 + i },
                opts: IaesOptions::default(),
                decompose: None,
            })
            .collect()
    }

    #[test]
    fn parallel_matches_sequential() {
        let js = jobs(6);
        let seq = run_parallel(&js, 1).unwrap();
        let par = run_parallel(&js, 4).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.name, b.name);
            assert!((a.report.minimum - b.report.minimum).abs() < 1e-9);
            assert_eq!(a.report.minimizer, b.report.minimizer);
        }
    }

    #[test]
    fn progress_callback_fires() {
        let js = jobs(3);
        let mut seen = Vec::new();
        run_with_progress(&js, |i, r| seen.push((i, r.name.clone()))).unwrap();
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[2].0, 2);
    }

    #[test]
    fn empty_job_list() {
        assert!(run_parallel(&[], 4).unwrap().is_empty());
    }

    #[test]
    fn panic_payloads_render_with_message() {
        let p = catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 7");
        let p = catch_unwind(|| panic!("static message")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "static message");
        let p = catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }

    #[test]
    fn panicking_job_surfaces_named_error() {
        // A panicking job must come back as an error naming the job
        // index and spec instead of poisoning the collector.
        let job = JobSpec {
            name: "exploder".into(),
            workload: WorkloadSpec::Iwata { p: 12 },
            opts: IaesOptions::default(),
            decompose: None,
        };
        let err = run_caught_with(3, &job, || panic!("oracle blew up")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("job 3"), "{msg}");
        assert!(msg.contains("exploder"), "{msg}");
        assert!(msg.contains("iwata(p=12)"), "{msg}");
        assert!(msg.contains("oracle blew up"), "{msg}");
        // Non-panicking path is unchanged.
        let ok = run_caught(0, &job).unwrap();
        assert_eq!(ok.name, "exploder");
    }
}
