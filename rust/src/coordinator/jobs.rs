//! Declarative experiment jobs: workload × solver × rules × backend.

use crate::decompose::{solve_decomposed, DecomposableFn, DecomposeOptions};
use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions, IaesReport, SolverChoice};
use crate::screening::{RuleSet, Screener};
use crate::solvers::frankwolfe::FwOptions;
use crate::solvers::minnorm::MinNormOptions;
use crate::submodular::Submodular;
use crate::workloads::images::{benchmark_suite, ImageInstance};
use crate::workloads::two_moons::{TwoMoons, TwoMoonsParams};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Screening backend selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA if artifacts exist, rust otherwise.
    Auto,
    /// Reference rust rules.
    Rust,
    /// Require the AOT XLA kernel (error if artifacts are missing).
    Xla,
}

impl BackendChoice {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendChoice::Auto),
            "rust" => Ok(BackendChoice::Rust),
            "xla" => Ok(BackendChoice::Xla),
            other => bail!("unknown backend `{other}` (auto|rust|xla)"),
        }
    }

    /// Materialize the screener (None = engine default, i.e. rust rules).
    pub fn screener(&self) -> Result<Option<Arc<dyn Screener>>> {
        match self {
            BackendChoice::Rust => Ok(None),
            BackendChoice::Auto => Ok(Some(crate::runtime::best_screener())),
            BackendChoice::Xla => {
                let s = crate::runtime::XlaScreener::at_default()?;
                Ok(Some(Arc::new(s)))
            }
        }
    }
}

/// What problem instance a job solves.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// Two-moons with `p` points (kernel-cut objective unless `use_mi`).
    TwoMoons {
        /// Number of points.
        p: usize,
        /// Use the exact GP mutual-information objective.
        use_mi: bool,
        /// Seed.
        seed: u64,
    },
    /// One of the five synthetic segmentation scenes, scaled.
    Image {
        /// Index into the benchmark suite (0..5).
        index: usize,
        /// Size multiplier.
        scale: f64,
    },
    /// Iwata's test function (micro/ablation workload).
    Iwata {
        /// Ground-set size.
        p: usize,
    },
}

impl WorkloadSpec {
    /// Build the submodular objective.
    pub fn build(&self) -> Result<Box<dyn Submodular>> {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => {
                let tm = TwoMoons::generate(TwoMoonsParams { p, seed, ..Default::default() });
                if use_mi {
                    Ok(Box::new(tm.gaussian_mi(0.1)))
                } else {
                    Ok(Box::new(tm.knn_cut(10, 1.0)))
                }
            }
            WorkloadSpec::Image { index, scale } => {
                let mut suite = benchmark_suite(scale);
                anyhow::ensure!(index < suite.len(), "image index out of range");
                let img: ImageInstance = suite.swap_remove(index);
                Ok(Box::new(img.cut_fn()))
            }
            WorkloadSpec::Iwata { p } => {
                Ok(Box::new(crate::submodular::iwata::IwataFn::new(p)))
            }
        }
    }

    /// Build the *decomposed* form of the same objective, for workloads
    /// that have one: two-moons kNN cut → per-point stars + label term,
    /// images → grid chains + unary term. Errors for workloads without a
    /// decomposition (Iwata, the GP mutual-information objective).
    pub fn build_decomposed(&self) -> Result<DecomposableFn> {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => {
                anyhow::ensure!(
                    !use_mi,
                    "the GP mutual-information objective has no decomposition"
                );
                let tm = TwoMoons::generate(TwoMoonsParams { p, seed, ..Default::default() });
                Ok(tm.knn_cut_decomposition(10, 1.0))
            }
            WorkloadSpec::Image { index, scale } => {
                let mut suite = benchmark_suite(scale);
                anyhow::ensure!(index < suite.len(), "image index out of range");
                suite.swap_remove(index).cut_decomposition()
            }
            WorkloadSpec::Iwata { .. } => {
                bail!("the Iwata workload has no decomposition")
            }
        }
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, .. } => {
                format!("two-moons(p={p}{})", if use_mi { ",mi" } else { "" })
            }
            WorkloadSpec::Image { index, scale } => {
                format!("image{}(x{scale})", index + 1)
            }
            WorkloadSpec::Iwata { p } => format!("iwata(p={p})"),
        }
    }
}

/// Solver selection by name.
pub fn solver_choice(name: &str) -> Result<SolverChoice> {
    match name.to_ascii_lowercase().as_str() {
        "minnorm" | "min-norm" => Ok(SolverChoice::MinNorm(MinNormOptions::default())),
        "fw" | "frank-wolfe" | "pairwise-fw" => {
            Ok(SolverChoice::FrankWolfe(FwOptions::default()))
        }
        "plain-fw" => Ok(SolverChoice::FrankWolfe(FwOptions {
            variant: crate::solvers::frankwolfe::FwVariant::Plain,
            ..Default::default()
        })),
        other => bail!("unknown solver `{other}` (minnorm|fw|plain-fw)"),
    }
}

/// Rule-set selection by name.
pub fn rule_set(name: &str) -> Result<RuleSet> {
    match name.to_ascii_lowercase().as_str() {
        "all" | "iaes" => Ok(RuleSet::all()),
        "aes" => Ok(RuleSet::aes_only()),
        "ies" => Ok(RuleSet::ies_only()),
        "pair1" => Ok(RuleSet::pair1_only()),
        "pair2" => Ok(RuleSet::pair2_only()),
        "none" | "off" => Ok(RuleSet::none()),
        other => bail!("unknown rule set `{other}` (all|aes|ies|pair1|pair2|none)"),
    }
}

/// One experiment job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Display name.
    pub name: String,
    /// Problem instance.
    pub workload: WorkloadSpec,
    /// IAES engine options.
    pub opts: IaesOptions,
    /// Solve through the decomposable block solver (`Some`) instead of
    /// the monolithic `opts.solver` (`None`). Requires a workload with a
    /// decomposition ([`WorkloadSpec::build_decomposed`]).
    pub decompose: Option<DecomposeOptions>,
}

/// A completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job name.
    pub name: String,
    /// Total wall time of the solve.
    pub wall: Duration,
    /// Full engine report.
    pub report: IaesReport,
}

impl JobSpec {
    /// Execute the job (builds the oracle, runs Algorithm 2 — through
    /// the block solver when `decompose` is set).
    pub fn run(&self) -> Result<JobResult> {
        let report;
        let wall;
        if let Some(dopts) = self.decompose {
            let f = self.workload.build_decomposed()?;
            let t0 = Instant::now();
            report = solve_decomposed(&f, &self.opts, dopts)?;
            wall = t0.elapsed();
        } else {
            let f = self.workload.build()?;
            let t0 = Instant::now();
            report = solve_sfm_with_screening(f.as_ref(), &self.opts)?;
            wall = t0.elapsed();
        }
        Ok(JobResult { name: self.name.clone(), wall, report })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("RUST").unwrap(), BackendChoice::Rust);
        assert!(BackendChoice::parse("gpu").is_err());
    }

    #[test]
    fn rule_and_solver_parse() {
        assert!(rule_set("all").unwrap().aes2);
        assert!(!rule_set("aes").unwrap().ies1);
        assert!(rule_set("banana").is_err());
        assert!(solver_choice("minnorm").is_ok());
        assert!(solver_choice("fw").is_ok());
        assert!(solver_choice("simplex").is_err());
    }

    #[test]
    fn iwata_job_runs() {
        let job = JobSpec {
            name: "iwata-20".into(),
            workload: WorkloadSpec::Iwata { p: 20 },
            opts: IaesOptions::default(),
            decompose: None,
        };
        let res = job.run().unwrap();
        assert!(res.report.minimum < 0.0);
        assert!(res.wall > Duration::ZERO);
    }

    #[test]
    fn two_moons_job_runs() {
        let job = JobSpec {
            name: "tm-40".into(),
            workload: WorkloadSpec::TwoMoons { p: 40, use_mi: false, seed: 3 },
            opts: IaesOptions::default(),
            decompose: None,
        };
        let res = job.run().unwrap();
        assert!(res.report.final_gap < 1e-6 || res.report.emptied);
    }

    #[test]
    fn workload_labels() {
        assert_eq!(
            WorkloadSpec::TwoMoons { p: 100, use_mi: true, seed: 0 }.label(),
            "two-moons(p=100,mi)"
        );
        assert_eq!(WorkloadSpec::Image { index: 0, scale: 1.0 }.label(), "image1(x1)");
    }
}
