//! Declarative experiment jobs: workload × solver × rules × backend.
//!
//! Jobs are constructed programmatically by the experiment suites and
//! parsed from JSON by the resident serve mode
//! ([`JobSpec::parse`] ⇄ [`JobSpec::to_json`]); parse errors name the
//! offending field by dotted path (`workload.p: expected a non-negative
//! integer, got a string`) so a rejected line in a batch or serve stream
//! is diagnosable without re-reading the whole spec.

use crate::coordinator::json::Json;
use crate::decompose::{solve_decomposed, DecomposableFn, DecomposeOptions};
use crate::obs::trace::TraceSink;
use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions, IaesReport, SolverChoice};
use crate::screening::{RuleSet, Screener};
use crate::solvers::frankwolfe::{FwOptions, FwVariant};
use crate::solvers::minnorm::MinNormOptions;
use crate::submodular::Submodular;
use crate::workloads::images::{benchmark_suite, ImageInstance};
use crate::workloads::two_moons::{TwoMoons, TwoMoonsParams};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Screening backend selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA if artifacts exist, rust otherwise.
    Auto,
    /// Reference rust rules.
    Rust,
    /// Require the AOT XLA kernel (error if artifacts are missing).
    Xla,
}

impl BackendChoice {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendChoice::Auto),
            "rust" => Ok(BackendChoice::Rust),
            "xla" => Ok(BackendChoice::Xla),
            other => bail!("unknown backend `{other}` (auto|rust|xla)"),
        }
    }

    /// Materialize the screener (None = engine default, i.e. rust rules).
    pub fn screener(&self) -> Result<Option<Arc<dyn Screener>>> {
        match self {
            BackendChoice::Rust => Ok(None),
            BackendChoice::Auto => Ok(Some(crate::runtime::best_screener())),
            BackendChoice::Xla => {
                let s = crate::runtime::XlaScreener::at_default()?;
                Ok(Some(Arc::new(s)))
            }
        }
    }
}

/// What problem instance a job solves.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// Two-moons with `p` points (kernel-cut objective unless `use_mi`).
    TwoMoons {
        /// Number of points.
        p: usize,
        /// Use the exact GP mutual-information objective.
        use_mi: bool,
        /// Seed.
        seed: u64,
    },
    /// One of the five synthetic segmentation scenes, scaled.
    Image {
        /// Index into the benchmark suite (0..5).
        index: usize,
        /// Size multiplier.
        scale: f64,
    },
    /// Iwata's test function (micro/ablation workload).
    Iwata {
        /// Ground-set size.
        p: usize,
    },
}

impl WorkloadSpec {
    /// Build the submodular objective.
    pub fn build(&self) -> Result<Box<dyn Submodular>> {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => {
                let tm = TwoMoons::generate(TwoMoonsParams { p, seed, ..Default::default() });
                if use_mi {
                    Ok(Box::new(tm.gaussian_mi(0.1)))
                } else {
                    Ok(Box::new(tm.knn_cut(10, 1.0)))
                }
            }
            WorkloadSpec::Image { index, scale } => {
                let mut suite = benchmark_suite(scale);
                anyhow::ensure!(index < suite.len(), "image index out of range");
                let img: ImageInstance = suite.swap_remove(index);
                Ok(Box::new(img.cut_fn()))
            }
            WorkloadSpec::Iwata { p } => {
                Ok(Box::new(crate::submodular::iwata::IwataFn::new(p)))
            }
        }
    }

    /// Build the *decomposed* form of the same objective, for workloads
    /// that have one: two-moons kNN cut → per-point stars + label term,
    /// images → grid chains + unary term. Errors for workloads without a
    /// decomposition (Iwata, the GP mutual-information objective).
    pub fn build_decomposed(&self) -> Result<DecomposableFn> {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => {
                anyhow::ensure!(
                    !use_mi,
                    "the GP mutual-information objective has no decomposition"
                );
                let tm = TwoMoons::generate(TwoMoonsParams { p, seed, ..Default::default() });
                Ok(tm.knn_cut_decomposition(10, 1.0))
            }
            WorkloadSpec::Image { index, scale } => {
                let mut suite = benchmark_suite(scale);
                anyhow::ensure!(index < suite.len(), "image index out of range");
                suite.swap_remove(index).cut_decomposition()
            }
            WorkloadSpec::Iwata { .. } => {
                bail!("the Iwata workload has no decomposition")
            }
        }
    }

    /// Build the objective behind a shareable, thread-safe handle — the
    /// serve-mode instance cache stores these so repeated jobs on the
    /// same workload skip the (often dominant) oracle construction and
    /// share one immutable instance across worker threads. Oracles are
    /// plain data (`Submodular: Sync`, no interior mutability), so
    /// sharing never affects a trajectory.
    pub fn build_shared(&self) -> Result<Arc<dyn Submodular + Send + Sync>> {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => {
                let tm = TwoMoons::generate(TwoMoonsParams { p, seed, ..Default::default() });
                if use_mi {
                    Ok(Arc::new(tm.gaussian_mi(0.1)))
                } else {
                    Ok(Arc::new(tm.knn_cut(10, 1.0)))
                }
            }
            WorkloadSpec::Image { index, scale } => {
                let mut suite = benchmark_suite(scale);
                anyhow::ensure!(index < suite.len(), "image index out of range");
                let img: ImageInstance = suite.swap_remove(index);
                Ok(Arc::new(img.cut_fn()))
            }
            WorkloadSpec::Iwata { p } => {
                Ok(Arc::new(crate::submodular::iwata::IwataFn::new(p)))
            }
        }
    }

    /// Cache key for the serve-mode instance cache: two specs build the
    /// same immutable oracle iff their keys match (the spec is the full
    /// construction recipe — workload kind plus every parameter).
    pub fn cache_key(&self) -> String {
        format!("{self:?}")
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, .. } => {
                format!("two-moons(p={p}{})", if use_mi { ",mi" } else { "" })
            }
            WorkloadSpec::Image { index, scale } => {
                format!("image{}(x{scale})", index + 1)
            }
            WorkloadSpec::Iwata { p } => format!("iwata(p={p})"),
        }
    }

    /// Parse from a JSON object: `{"kind": "iwata", "p": 20}`,
    /// `{"kind": "two-moons", "p": 100, "use_mi": false, "seed": 7}`, or
    /// `{"kind": "image", "index": 0, "scale": 1.0}`. Errors name the
    /// offending field (`workload.p: …`).
    pub fn parse(v: &Json) -> Result<Self> {
        if !matches!(v, Json::Obj(_)) {
            bail!("workload: expected an object, got {}", kind_name(v));
        }
        let kind = req_str(v, "workload.", "kind")?;
        match kind.as_str() {
            "two-moons" => {
                reject_unknown(v, "workload.", &["kind", "p", "use_mi", "seed"])?;
                Ok(WorkloadSpec::TwoMoons {
                    p: req_usize(v, "workload.", "p")?,
                    use_mi: opt_bool(v, "workload.", "use_mi", false)?,
                    seed: opt_usize(v, "workload.", "seed", 0)? as u64,
                })
            }
            "image" => {
                reject_unknown(v, "workload.", &["kind", "index", "scale"])?;
                Ok(WorkloadSpec::Image {
                    index: req_usize(v, "workload.", "index")?,
                    scale: opt_f64(v, "workload.", "scale", 1.0)?,
                })
            }
            "iwata" => {
                reject_unknown(v, "workload.", &["kind", "p"])?;
                Ok(WorkloadSpec::Iwata { p: req_usize(v, "workload.", "p")? })
            }
            other => bail!(
                "workload.kind: unknown workload `{other}` (two-moons|image|iwata)"
            ),
        }
    }

    /// Serialize to the JSON object [`parse`](Self::parse) accepts.
    pub fn to_json(&self) -> Json {
        match *self {
            WorkloadSpec::TwoMoons { p, use_mi, seed } => Json::obj(vec![
                ("kind", Json::Str("two-moons".into())),
                ("p", Json::Num(p as f64)),
                ("use_mi", Json::Bool(use_mi)),
                ("seed", Json::Num(seed as f64)),
            ]),
            WorkloadSpec::Image { index, scale } => Json::obj(vec![
                ("kind", Json::Str("image".into())),
                ("index", Json::Num(index as f64)),
                ("scale", Json::Num(scale)),
            ]),
            WorkloadSpec::Iwata { p } => Json::obj(vec![
                ("kind", Json::Str("iwata".into())),
                ("p", Json::Num(p as f64)),
            ]),
        }
    }
}

/// Human-readable JSON value kind, for field errors (shared with the
/// serve-mode request envelope parser).
pub(crate) fn kind_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "a boolean",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    }
}

/// Reject fields outside `allowed`, naming the first offender — a typo'd
/// option must fail the job, not silently fall back to a default.
fn reject_unknown(v: &Json, ctx: &str, allowed: &[&str]) -> Result<()> {
    if let Json::Obj(pairs) = v {
        for (k, _) in pairs {
            if !allowed.contains(&k.as_str()) {
                bail!("{ctx}{k}: unknown field (allowed: {})", allowed.join(", "));
            }
        }
    }
    Ok(())
}

fn req_str(v: &Json, ctx: &str, field: &str) -> Result<String> {
    match v.get(field) {
        None => bail!("{ctx}{field}: required field is missing"),
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(other) => bail!("{ctx}{field}: expected a string, got {}", kind_name(other)),
    }
}

fn opt_str(v: &Json, ctx: &str, field: &str) -> Result<Option<String>> {
    match v.get(field) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(other) => bail!("{ctx}{field}: expected a string, got {}", kind_name(other)),
    }
}

fn opt_f64(v: &Json, ctx: &str, field: &str, default: f64) -> Result<f64> {
    match v.get(field) {
        None => Ok(default),
        Some(Json::Num(x)) if x.is_finite() => Ok(*x),
        Some(other) => bail!(
            "{ctx}{field}: expected a finite number, got {}",
            kind_name(other)
        ),
    }
}

fn parse_usize(v: &Json, ctx: &str, field: &str) -> Result<usize> {
    match v {
        Json::Num(x) if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 => Ok(*x as usize),
        other => bail!(
            "{ctx}{field}: expected a non-negative integer, got {}",
            kind_name(other)
        ),
    }
}

fn req_usize(v: &Json, ctx: &str, field: &str) -> Result<usize> {
    match v.get(field) {
        None => bail!("{ctx}{field}: required field is missing"),
        Some(x) => parse_usize(x, ctx, field),
    }
}

fn opt_usize(v: &Json, ctx: &str, field: &str, default: usize) -> Result<usize> {
    match v.get(field) {
        None => Ok(default),
        Some(x) => parse_usize(x, ctx, field),
    }
}

fn opt_bool(v: &Json, ctx: &str, field: &str, default: bool) -> Result<bool> {
    match v.get(field) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(other) => bail!("{ctx}{field}: expected a boolean, got {}", kind_name(other)),
    }
}

/// Solver selection by name.
pub fn solver_choice(name: &str) -> Result<SolverChoice> {
    match name.to_ascii_lowercase().as_str() {
        "minnorm" | "min-norm" => Ok(SolverChoice::MinNorm(MinNormOptions::default())),
        "fw" | "frank-wolfe" | "pairwise-fw" => {
            Ok(SolverChoice::FrankWolfe(FwOptions::default()))
        }
        "plain-fw" => Ok(SolverChoice::FrankWolfe(FwOptions {
            variant: crate::solvers::frankwolfe::FwVariant::Plain,
            ..Default::default()
        })),
        other => bail!("unknown solver `{other}` (minnorm|fw|plain-fw)"),
    }
}

/// Canonical name of a solver choice (inverse of [`solver_choice`];
/// tuned option fields are not round-tripped, only the family).
pub fn solver_name(choice: &SolverChoice) -> &'static str {
    match choice {
        SolverChoice::MinNorm(_) => "minnorm",
        SolverChoice::FrankWolfe(o) if matches!(o.variant, FwVariant::Plain) => "plain-fw",
        SolverChoice::FrankWolfe(_) => "fw",
    }
}

/// Canonical name of a rule set (inverse of [`rule_set`]). Only the
/// named configurations have names; ad-hoc flag combinations (reachable
/// programmatically, never from [`rule_set`]) report as `"custom"`.
pub fn rule_set_name(rules: RuleSet) -> &'static str {
    if rules == RuleSet::all() {
        "all"
    } else if rules == RuleSet::aes_only() {
        "aes"
    } else if rules == RuleSet::ies_only() {
        "ies"
    } else if rules == RuleSet::pair1_only() {
        "pair1"
    } else if rules == RuleSet::pair2_only() {
        "pair2"
    } else if rules == RuleSet::none() {
        "none"
    } else {
        "custom"
    }
}

/// Rule-set selection by name.
pub fn rule_set(name: &str) -> Result<RuleSet> {
    match name.to_ascii_lowercase().as_str() {
        "all" | "iaes" => Ok(RuleSet::all()),
        "aes" => Ok(RuleSet::aes_only()),
        "ies" => Ok(RuleSet::ies_only()),
        "pair1" => Ok(RuleSet::pair1_only()),
        "pair2" => Ok(RuleSet::pair2_only()),
        "none" | "off" => Ok(RuleSet::none()),
        other => bail!("unknown rule set `{other}` (all|aes|ies|pair1|pair2|none)"),
    }
}

/// One experiment job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Display name.
    pub name: String,
    /// Problem instance.
    pub workload: WorkloadSpec,
    /// IAES engine options.
    pub opts: IaesOptions,
    /// Solve through the decomposable block solver (`Some`) instead of
    /// the monolithic `opts.solver` (`None`). Requires a workload with a
    /// decomposition ([`WorkloadSpec::build_decomposed`]).
    pub decompose: Option<DecomposeOptions>,
}

/// A completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job name.
    pub name: String,
    /// Total wall time of the solve.
    pub wall: Duration,
    /// Full engine report.
    pub report: IaesReport,
}

impl JobSpec {
    /// Execute the job (builds the oracle, runs Algorithm 2 — through
    /// the block solver when `decompose` is set).
    pub fn run(&self) -> Result<JobResult> {
        let report;
        let wall;
        if let Some(dopts) = self.decompose {
            let f = self.workload.build_decomposed()?;
            let t0 = Instant::now();
            report = solve_decomposed(&f, &self.opts, dopts)?;
            wall = t0.elapsed();
        } else {
            let f = self.workload.build()?;
            let t0 = Instant::now();
            report = solve_sfm_with_screening(f.as_ref(), &self.opts)?;
            wall = t0.elapsed();
        }
        Ok(JobResult { name: self.name.clone(), wall, report })
    }

    /// Parse a job from a JSON object, e.g.
    /// `{"name": "tm", "workload": {"kind": "two-moons", "p": 100},
    ///   "eps": 1e-6, "solver": "minnorm", "rules": "all"}`.
    ///
    /// Unknown fields are rejected by name; every error names the
    /// offending field by dotted path. Callers parsing a batch add the
    /// job index via `.with_context(|| format!("job {i}"))`.
    pub fn parse(v: &Json) -> Result<JobSpec> {
        if !matches!(v, Json::Obj(_)) {
            bail!("job: expected an object, got {}", kind_name(v));
        }
        reject_unknown(
            v,
            "",
            &[
                "name",
                "workload",
                "eps",
                "rho",
                "max_iters",
                "solver",
                "rules",
                "threads",
                "min_reduction_frac",
                "record_history",
                "trace",
                "decompose",
            ],
        )?;
        let workload = match v.get("workload") {
            None => bail!("workload: required field is missing"),
            Some(w) => WorkloadSpec::parse(w)?,
        };
        let eps = opt_f64(v, "", "eps", 1e-6)?;
        if eps <= 0.0 {
            bail!("eps: must be positive, got {eps}");
        }
        let rho = opt_f64(v, "", "rho", 0.5)?;
        if !(rho > 0.0 && rho < 1.0) {
            bail!("rho: must lie in (0,1), got {rho}");
        }
        let min_reduction_frac = opt_f64(v, "", "min_reduction_frac", 0.2)?;
        if !(0.0..=1.0).contains(&min_reduction_frac) {
            bail!("min_reduction_frac: must lie in [0,1], got {min_reduction_frac}");
        }
        let solver = match opt_str(v, "", "solver")? {
            None => SolverChoice::default(),
            Some(name) => solver_choice(&name).map_err(|e| anyhow::anyhow!("solver: {e}"))?,
        };
        let rules = match opt_str(v, "", "rules")? {
            None => RuleSet::all(),
            Some(name) => rule_set(&name).map_err(|e| anyhow::anyhow!("rules: {e}"))?,
        };
        let opts = IaesOptions {
            eps,
            rho,
            rules,
            solver,
            max_iters: opt_usize(v, "", "max_iters", 100_000)?,
            record_history: opt_bool(v, "", "record_history", false)?,
            min_reduction_frac,
            threads: opt_usize(v, "", "threads", 1)?,
            // Each parsed job gets its own fresh sink: the engine folds
            // a summary into the report, so serve responses carry the
            // boundary telemetry without any cross-job sharing.
            trace: opt_bool(v, "", "trace", false)?.then(TraceSink::new),
            ..Default::default()
        };
        let decompose = match v.get("decompose") {
            None | Some(Json::Bool(false)) => None,
            Some(Json::Bool(true)) => Some(DecomposeOptions::default()),
            Some(d @ Json::Obj(_)) => {
                reject_unknown(
                    d,
                    "decompose.",
                    &["threads", "inner_tol", "max_inner", "gauss_seidel", "warm_duals"],
                )?;
                let base = DecomposeOptions::default();
                Some(DecomposeOptions {
                    threads: opt_usize(d, "decompose.", "threads", base.threads)?,
                    inner_tol: opt_f64(d, "decompose.", "inner_tol", base.inner_tol)?,
                    max_inner: opt_usize(d, "decompose.", "max_inner", base.max_inner)?,
                    gauss_seidel: opt_bool(d, "decompose.", "gauss_seidel", base.gauss_seidel)?,
                    warm_duals: opt_bool(d, "decompose.", "warm_duals", base.warm_duals)?,
                    ..base
                })
            }
            Some(other) => bail!(
                "decompose: expected a boolean or an object, got {}",
                kind_name(other)
            ),
        };
        let name = match opt_str(v, "", "name")? {
            Some(n) => n,
            None => workload.label(),
        };
        Ok(JobSpec { name, workload, opts, decompose })
    }

    /// Serialize to the JSON object [`parse`](Self::parse) accepts
    /// (engine options not expressible in the job grammar — screener
    /// backend, cancel token, warm-restart toggles — are omitted).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("workload", self.workload.to_json()),
            ("eps", Json::Num(self.opts.eps)),
            ("rho", Json::Num(self.opts.rho)),
            ("max_iters", Json::Num(self.opts.max_iters as f64)),
            ("solver", Json::Str(solver_name(&self.opts.solver).into())),
            ("rules", Json::Str(rule_set_name(self.opts.rules).into())),
            ("threads", Json::Num(self.opts.threads as f64)),
            ("min_reduction_frac", Json::Num(self.opts.min_reduction_frac)),
            ("record_history", Json::Bool(self.opts.record_history)),
            ("trace", Json::Bool(self.opts.trace.is_some())),
        ];
        if let Some(d) = self.decompose {
            pairs.push((
                "decompose",
                Json::obj(vec![
                    ("threads", Json::Num(d.threads as f64)),
                    ("inner_tol", Json::Num(d.inner_tol)),
                    ("max_inner", Json::Num(d.max_inner as f64)),
                    ("gauss_seidel", Json::Bool(d.gauss_seidel)),
                    ("warm_duals", Json::Bool(d.warm_duals)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("RUST").unwrap(), BackendChoice::Rust);
        assert!(BackendChoice::parse("gpu").is_err());
    }

    #[test]
    fn rule_and_solver_parse() {
        assert!(rule_set("all").unwrap().aes2);
        assert!(!rule_set("aes").unwrap().ies1);
        assert!(rule_set("banana").is_err());
        assert!(solver_choice("minnorm").is_ok());
        assert!(solver_choice("fw").is_ok());
        assert!(solver_choice("simplex").is_err());
    }

    #[test]
    fn iwata_job_runs() {
        let job = JobSpec {
            name: "iwata-20".into(),
            workload: WorkloadSpec::Iwata { p: 20 },
            opts: IaesOptions::default(),
            decompose: None,
        };
        let res = job.run().unwrap();
        assert!(res.report.minimum < 0.0);
        assert!(res.wall > Duration::ZERO);
    }

    #[test]
    fn two_moons_job_runs() {
        let job = JobSpec {
            name: "tm-40".into(),
            workload: WorkloadSpec::TwoMoons { p: 40, use_mi: false, seed: 3 },
            opts: IaesOptions::default(),
            decompose: None,
        };
        let res = job.run().unwrap();
        assert!(res.report.final_gap < 1e-6 || res.report.emptied);
    }

    #[test]
    fn job_parse_roundtrips_through_to_json() {
        let line = r#"{"name":"tm","workload":{"kind":"two-moons","p":60,"seed":7},
            "eps":1e-7,"rho":0.4,"solver":"fw","rules":"aes","threads":2,
            "decompose":{"threads":3,"gauss_seidel":false}}"#;
        let job = JobSpec::parse(&Json::parse(line).unwrap()).unwrap();
        assert_eq!(job.name, "tm");
        assert!(matches!(job.workload, WorkloadSpec::TwoMoons { p: 60, seed: 7, .. }));
        assert_eq!(job.opts.eps, 1e-7);
        assert_eq!(job.opts.rho, 0.4);
        assert_eq!(job.opts.rules, RuleSet::aes_only());
        assert_eq!(job.opts.threads, 2);
        let d = job.decompose.unwrap();
        assert_eq!(d.threads, 3);
        assert!(!d.gauss_seidel);
        // parse → to_json → parse is a fixed point.
        let back = JobSpec::parse(&job.to_json()).unwrap();
        assert_eq!(back.to_json().to_string(), job.to_json().to_string());
    }

    #[test]
    fn job_parse_defaults_and_derived_name() {
        let job = JobSpec::parse(
            &Json::parse(r#"{"workload":{"kind":"iwata","p":12}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(job.name, "iwata(p=12)");
        assert_eq!(job.opts.eps, 1e-6);
        assert!(!job.opts.record_history);
        assert!(job.decompose.is_none());
        // `decompose: true` selects the default block-solver options.
        let job = JobSpec::parse(
            &Json::parse(r#"{"workload":{"kind":"iwata","p":12},"decompose":true}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(job.decompose.is_some());
    }

    #[test]
    fn job_parse_errors_name_the_field() {
        let cases = [
            (r#"{"workload":{"kind":"iwata"}}"#, "workload.p"),
            (r#"{"workload":{"kind":"iwata","p":"big"}}"#, "workload.p"),
            (r#"{"workload":{"kind":"iwata","p":-3}}"#, "workload.p"),
            (r#"{"workload":{"kind":"iwata","p":2.5}}"#, "workload.p"),
            (r#"{"workload":{"kind":"warp","p":4}}"#, "workload.kind"),
            (r#"{"workload":{"kind":"iwata","p":4,"scale":2}}"#, "workload.scale"),
            (r#"{"eps":1e-6}"#, "workload"),
            (r#"{"workload":{"kind":"iwata","p":4},"eps":-1}"#, "eps"),
            (r#"{"workload":{"kind":"iwata","p":4},"rho":1.5}"#, "rho"),
            (r#"{"workload":{"kind":"iwata","p":4},"solver":"simplex"}"#, "solver"),
            (r#"{"workload":{"kind":"iwata","p":4},"rules":7}"#, "rules"),
            (r#"{"workload":{"kind":"iwata","p":4},"budget":9}"#, "budget"),
            (r#"{"workload":{"kind":"iwata","p":4},"decompose":{"x":1}}"#, "decompose.x"),
            (r#"{"workload":{"kind":"iwata","p":4},"decompose":3}"#, "decompose"),
            (r#"[1]"#, "expected an object"),
        ];
        for (doc, needle) in cases {
            let err = JobSpec::parse(&Json::parse(doc).unwrap())
                .map(|_| ())
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "`{doc}`: got `{err}`, wanted `{needle}`");
        }
    }

    #[test]
    fn shared_build_matches_boxed_build() {
        let wl = WorkloadSpec::Iwata { p: 16 };
        let boxed = wl.build().unwrap();
        let shared = wl.build_shared().unwrap();
        let opts = IaesOptions::default();
        let a = solve_sfm_with_screening(boxed.as_ref(), &opts).unwrap();
        let b = solve_sfm_with_screening(shared.as_ref(), &opts).unwrap();
        assert_eq!(a.minimum.to_bits(), b.minimum.to_bits());
        assert_eq!(a.minimizer, b.minimizer);
        assert_eq!(wl.cache_key(), WorkloadSpec::Iwata { p: 16 }.cache_key());
        assert_ne!(wl.cache_key(), WorkloadSpec::Iwata { p: 17 }.cache_key());
    }

    #[test]
    fn solver_and_rule_names_invert_the_parsers() {
        for name in ["minnorm", "fw", "plain-fw"] {
            assert_eq!(solver_name(&solver_choice(name).unwrap()), name);
        }
        for name in ["all", "aes", "ies", "pair1", "pair2", "none"] {
            assert_eq!(rule_set_name(rule_set(name).unwrap()), name);
        }
    }

    #[test]
    fn workload_labels() {
        assert_eq!(
            WorkloadSpec::TwoMoons { p: 100, use_mi: true, seed: 0 }.label(),
            "two-moons(p=100,mi)"
        );
        assert_eq!(WorkloadSpec::Image { index: 0, scale: 1.0 }.label(), "image1(x1)");
    }
}
