//! Wall-clock measurement utilities shared by the coordinator and the
//! bench harness (criterion is unavailable offline — see DESIGN.md
//! §Substitutions — so the harness carries its own warmup + robust-summary
//! machinery), plus the machine-readable `BENCH_*.json` trajectory writer
//! that lets successive PRs track perf regressions (see BENCHMARKS.md).

use crate::coordinator::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// A simple resumable stopwatch.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    /// New, stopped, zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or resume) timing.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop timing, accumulating the elapsed span.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Accumulated time (excludes a currently running span).
    pub fn total(&self) -> Duration {
        self.total
    }
}

/// Robust summary of repeated measurements.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean (seconds).
    pub mean: f64,
    /// Median (seconds).
    pub median: f64,
    /// Minimum (seconds).
    pub min: f64,
    /// Maximum (seconds).
    pub max: f64,
    /// Sample standard deviation (seconds).
    pub std: f64,
}

impl Summary {
    /// Summarize a set of durations. Panics on empty input.
    pub fn of(samples: &[Duration]) -> Self {
        assert!(!samples.is_empty());
        let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        Self::of_secs(secs)
    }

    /// Summarize raw seconds. Degenerate samples (NaN from a downstream
    /// division, infinities) are ordered by `f64::total_cmp` — NaN sorts
    /// last — instead of panicking mid-report the way the old
    /// `partial_cmp(..).unwrap()` comparator did. Panics on empty input.
    pub fn of_secs(mut secs: Vec<f64>) -> Self {
        assert!(!secs.is_empty());
        secs.sort_by(f64::total_cmp);
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            secs[n / 2]
        } else {
            0.5 * (secs[n / 2 - 1] + secs[n / 2])
        };
        let var = if n > 1 {
            secs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary { n, mean, median, min: secs[0], max: secs[n - 1], std: var.sqrt() }
    }
}

/// Time one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Bench a closure: `warmup` unmeasured runs, then `reps` measured runs.
/// Returns the summary and the last output.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> (Summary, T) {
    assert!(reps > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let (out, dt) = time_once(&mut f);
        samples.push(dt);
        last = Some(out);
    }
    (Summary::of(&samples), last.unwrap())
}

/// One machine-readable bench row (schema documented in BENCHMARKS.md).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Operation id, stable across PRs (e.g. `greedy/cut`, `minnorm-iter`).
    pub op: String,
    /// Problem size.
    pub p: usize,
    /// Median seconds per operation.
    pub median_s: f64,
    /// Minimum seconds per operation.
    pub min_s: f64,
    /// Throughput `1 / median_s`.
    pub ops_per_s: f64,
}

impl BenchRecord {
    /// Build from a measurement summary.
    pub fn new(op: &str, p: usize, s: &Summary) -> Self {
        BenchRecord {
            op: op.to_string(),
            p,
            median_s: s.median,
            min_s: s.min,
            ops_per_s: 1.0 / s.median,
        }
    }
}

/// Default location of `BENCH_<name>.json`: `$SFM_BENCH_JSON_DIR` if set,
/// else the repository root (one directory above the cargo manifest).
pub fn bench_json_path(name: &str) -> PathBuf {
    let dir = std::env::var("SFM_BENCH_JSON_DIR").ok();
    bench_json_path_in(dir.as_deref(), name)
}

/// Environment-independent core of [`bench_json_path`] (unit-testable
/// without mutating process-global state).
fn bench_json_path_in(dir: Option<&str>, name: &str) -> PathBuf {
    let file = format!("BENCH_{name}.json");
    if let Some(dir) = dir {
        return PathBuf::from(dir).join(file);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match manifest.parent() {
        Some(root) => root.join(file),
        None => manifest.join(file),
    }
}

/// Serialize bench records to the `BENCH_<name>.json` trajectory format.
pub fn bench_records_to_json(name: &str, records: &[BenchRecord]) -> Json {
    Json::obj(vec![
        ("schema_version", Json::Num(1.0)),
        ("bench", Json::Str(name.to_string())),
        (
            "records",
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("op", Json::Str(r.op.clone())),
                            ("p", Json::Num(r.p as f64)),
                            ("median_s", Json::Num(r.median_s)),
                            ("min_s", Json::Num(r.min_s)),
                            ("ops_per_s", Json::Num(r.ops_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write `BENCH_<name>.json` (returns the path written).
pub fn write_bench_json(name: &str, records: &[BenchRecord]) -> Result<PathBuf> {
    let path = bench_json_path(name);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let body = bench_records_to_json(name, records).to_string();
    std::fs::write(&path, body + "\n")
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Parse the records of a `BENCH_*.json` trajectory (see BENCHMARKS.md)
/// back into [`BenchRecord`]s — the read half of the regression
/// comparator.
pub fn parse_bench_records(json: &Json) -> Result<Vec<BenchRecord>> {
    let records = json
        .get("records")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing `records` array"))?;
    let mut out = Vec::with_capacity(records.len());
    for (i, rec) in records.iter().enumerate() {
        let op = rec
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("record {i}: missing `op`"))?;
        let num = |key: &str| -> Result<f64> {
            rec.get(key)
                .and_then(Json::as_num)
                .ok_or_else(|| anyhow::anyhow!("record {i} ({op}): missing `{key}`"))
        };
        let median_s = num("median_s")?;
        out.push(BenchRecord {
            op: op.to_string(),
            p: num("p")? as usize,
            median_s,
            min_s: num("min_s")?,
            ops_per_s: num("ops_per_s")?,
        });
    }
    Ok(out)
}

/// One op that regressed between two bench trajectories.
#[derive(Clone, Debug)]
pub struct BenchRegression {
    /// Operation id (`op@p`).
    pub op: String,
    /// Problem size.
    pub p: usize,
    /// Baseline median seconds.
    pub base_median_s: f64,
    /// New median seconds.
    pub new_median_s: f64,
    /// `new / base` ratio (> 1 is slower).
    pub ratio: f64,
}

/// Diff two bench trajectories: every `(op, p)` present in both is
/// compared by median, and any slowdown beyond `1 + tol_frac` (e.g.
/// `0.10` for the CI gate's 10%) is reported. Ops present in only one
/// trajectory are ignored — adding or retiring a bench row is not a
/// regression.
pub fn compare_bench_records(
    base: &[BenchRecord],
    new: &[BenchRecord],
    tol_frac: f64,
) -> Vec<BenchRegression> {
    let mut regressions = Vec::new();
    for b in base {
        let Some(n) = new.iter().find(|n| n.op == b.op && n.p == b.p) else {
            continue;
        };
        if !(b.median_s.is_finite() && n.median_s.is_finite()) || b.median_s <= 0.0 {
            continue;
        }
        let ratio = n.median_s / b.median_s;
        if ratio > 1.0 + tol_frac {
            regressions.push(BenchRegression {
                op: b.op.clone(),
                p: b.p,
                base_median_s: b.median_s,
                new_median_s: n.median_s,
                ratio,
            });
        }
    }
    regressions
}

/// Human-readable duration (adaptive unit).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let a = sw.total();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.total() > a);
        assert!(sw.total() >= Duration::from_millis(9));
    }

    #[test]
    fn summary_stats() {
        let samples: Vec<Duration> =
            [1, 2, 3, 4, 100].iter().map(|&ms| Duration::from_millis(ms)).collect();
        let s = Summary::of(&samples);
        assert_eq!(s.n, 5);
        assert!((s.median - 0.003).abs() < 1e-9);
        assert!((s.min - 0.001).abs() < 1e-9);
        assert!((s.max - 0.1).abs() < 1e-9);
        assert!(s.mean > s.median, "outlier pulls mean up");
    }

    #[test]
    fn summary_of_secs_survives_degenerate_samples() {
        // NaN (e.g. a zero-duration rep divided downstream) must not
        // panic the sort; total_cmp sends it to the tail.
        let s = Summary::of_secs(vec![0.002, f64::NAN, 0.001, 0.003]);
        assert_eq!(s.n, 4);
        assert!((s.min - 0.001).abs() < 1e-12);
        assert!(s.max.is_nan(), "NaN must sort last into max");
        // Median of [0.001, 0.002, 0.003, NaN] = avg of slots 1,2.
        assert!((s.median - 0.0025).abs() < 1e-12);
        // All-finite behaviour is unchanged.
        let s = Summary::of_secs(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        // Signed zeros and infinities order totally as well.
        let s = Summary::of_secs(vec![f64::INFINITY, -0.0, 0.0]);
        assert_eq!(s.min, -0.0);
        assert!(s.max.is_infinite());
    }

    fn rec(op: &str, p: usize, median: f64) -> BenchRecord {
        BenchRecord {
            op: op.into(),
            p,
            median_s: median,
            min_s: median * 0.9,
            ops_per_s: 1.0 / median,
        }
    }

    #[test]
    fn comparator_flags_only_real_regressions() {
        let base = vec![rec("greedy/cut", 256, 1e-3), rec("pav", 256, 2e-3)];
        let new = vec![
            rec("greedy/cut", 256, 1.05e-3), // +5%: within the gate
            rec("pav", 256, 2.4e-3),         // +20%: regression
            rec("restart/warm", 256, 1e-4),  // new row: ignored
        ];
        let regs = compare_bench_records(&base, &new, 0.10);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].op, "pav");
        assert!((regs[0].ratio - 1.2).abs() < 1e-9);
        // Identical trajectories never regress.
        assert!(compare_bench_records(&base, &base, 0.10).is_empty());
        // Different p never matches.
        let other = vec![rec("pav", 512, 9.0)];
        assert!(compare_bench_records(&base, &other, 0.10).is_empty());
    }

    #[test]
    fn comparator_roundtrips_through_json() {
        let records = vec![rec("greedy/cut", 4096, 1.2e-4), rec("minnorm-iter", 4096, 2.5e-4)];
        let text = bench_records_to_json("micro", &records).to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = parse_bench_records(&parsed).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].op, "greedy/cut");
        assert_eq!(back[0].p, 4096);
        assert!((back[0].median_s - 1.2e-4).abs() < 1e-18);
        assert!(compare_bench_records(&records, &back, 0.0).is_empty());
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0;
        let (summary, out) = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(summary.n, 5);
        assert_eq!(out, 7); // 2 warmup + 5 measured
    }

    #[test]
    fn bench_json_shape() {
        let samples: Vec<Duration> =
            [2, 4, 8].iter().map(|&ms| Duration::from_millis(ms)).collect();
        let s = Summary::of(&samples);
        let rec = BenchRecord::new("greedy/cut", 4096, &s);
        assert_eq!(rec.op, "greedy/cut");
        assert!((rec.median_s - 0.004).abs() < 1e-12);
        assert!((rec.ops_per_s - 250.0).abs() < 1e-6);
        let j = bench_records_to_json("micro", &[rec]).to_string();
        assert!(j.contains("\"bench\":\"micro\""), "{j}");
        assert!(j.contains("\"op\":\"greedy/cut\""), "{j}");
        assert!(j.contains("\"p\":4096"), "{j}");
        assert!(j.contains("\"schema_version\":1"), "{j}");
    }

    #[test]
    fn bench_json_path_resolution() {
        let p = bench_json_path_in(Some("/tmp/bench-dir"), "unit");
        assert_eq!(p, PathBuf::from("/tmp/bench-dir").join("BENCH_unit.json"));
        let p = bench_json_path_in(None, "micro");
        assert!(p.ends_with("BENCH_micro.json"), "{}", p.display());
        // Default lands at the repo root, one above the cargo manifest.
        assert!(!p.starts_with(env!("CARGO_MANIFEST_DIR")) || {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().is_none()
        });
    }

    #[test]
    fn fmt_adapts() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(2)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(2)).ends_with("µs"));
    }
}
