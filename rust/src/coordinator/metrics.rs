//! Wall-clock measurement utilities shared by the coordinator and the
//! bench harness (criterion is unavailable offline — see DESIGN.md
//! §Substitutions — so the harness carries its own warmup + robust-summary
//! machinery).

use std::time::{Duration, Instant};

/// A simple resumable stopwatch.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    /// New, stopped, zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or resume) timing.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop timing, accumulating the elapsed span.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Accumulated time (excludes a currently running span).
    pub fn total(&self) -> Duration {
        self.total
    }
}

/// Robust summary of repeated measurements.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean (seconds).
    pub mean: f64,
    /// Median (seconds).
    pub median: f64,
    /// Minimum (seconds).
    pub min: f64,
    /// Maximum (seconds).
    pub max: f64,
    /// Sample standard deviation (seconds).
    pub std: f64,
}

impl Summary {
    /// Summarize a set of durations. Panics on empty input.
    pub fn of(samples: &[Duration]) -> Self {
        assert!(!samples.is_empty());
        let mut secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            secs[n / 2]
        } else {
            0.5 * (secs[n / 2 - 1] + secs[n / 2])
        };
        let var = if n > 1 {
            secs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary { n, mean, median, min: secs[0], max: secs[n - 1], std: var.sqrt() }
    }
}

/// Time one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Bench a closure: `warmup` unmeasured runs, then `reps` measured runs.
/// Returns the summary and the last output.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> (Summary, T) {
    assert!(reps > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let (out, dt) = time_once(&mut f);
        samples.push(dt);
        last = Some(out);
    }
    (Summary::of(&samples), last.unwrap())
}

/// Human-readable duration (adaptive unit).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let a = sw.total();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.total() > a);
        assert!(sw.total() >= Duration::from_millis(9));
    }

    #[test]
    fn summary_stats() {
        let samples: Vec<Duration> =
            [1, 2, 3, 4, 100].iter().map(|&ms| Duration::from_millis(ms)).collect();
        let s = Summary::of(&samples);
        assert_eq!(s.n, 5);
        assert!((s.median - 0.003).abs() < 1e-9);
        assert!((s.min - 0.001).abs() < 1e-9);
        assert!((s.max - 0.1).abs() < 1e-9);
        assert!(s.mean > s.median, "outlier pulls mean up");
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0;
        let (summary, out) = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(summary.n, 5);
        assert_eq!(out, 7); // 2 warmup + 5 measured
    }

    #[test]
    fn fmt_adapts() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(2)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(2)).ends_with("µs"));
    }
}
