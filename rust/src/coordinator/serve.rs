//! Fault-isolated resident solve service (`sfm-screen serve`).
//!
//! A long-lived process that accepts newline-delimited [`JobSpec`] JSON
//! on stdin (and, optionally, on a unix socket) and streams one JSON
//! response line back per job. Design invariants:
//!
//! * **Admission control, not OOM.** Jobs enter a bounded queue; when it
//!   is full the job is *rejected immediately* with a structured
//!   `status: "rejected"` / `kind: "queue_full"` response instead of
//!   buffering without bound.
//! * **Fault isolation at the job boundary.** Each job runs under
//!   `catch_unwind`; a panicking solve produces a `kind: "panic"`
//!   response and the worker rebuilds its greedy-oracle pool before the
//!   next job, so one poisoned job can never wedge the service.
//! * **Deadlines are cooperative and safe.** A per-job deadline (from
//!   `deadline_ms` on the request, or `--deadline-ms`) arms a
//!   [`CancelToken`] checked by the IAES engine *only at major-iteration
//!   boundaries* — an expired job returns a partial report whose
//!   screened sets are still Lemma-2/3 safe, and an unfired token is
//!   bitwise inert.
//! * **Instance caching.** Monolithic jobs share one immutable oracle
//!   per workload spec ([`super::jobs::WorkloadSpec::cache_key`]):
//!   repeated solves on the same instance skip construction entirely.
//!
//! Responses carry the request's `id` verbatim plus a server-assigned
//! `seq`, a `status` (`ok` | `partial` | `error` | `rejected`), the
//! engine report (or `null`), a structured `error` object whose
//! `kind` is one of `invalid` | `queue_full` | `panic` | `numeric` |
//! `error`, the solve wall time `wall_s`, and `queue_wait_s` — how long
//! the job sat admitted before a worker picked it up (`null` for lines
//! that never reached the queue). Response *order* across concurrent
//! workers is not guaranteed — correlate by `id`/`seq`, never by line
//! position.
//!
//! **Telemetry.** Every admission decision and job outcome feeds a
//! process-wide [`MetricsRegistry`](crate::obs::MetricsRegistry) of
//! atomic counters, gauges, and fixed-bucket latency histograms. The
//! registry lives in the shared service state — *outside* the workers —
//! so counts survive contained job panics and the oracle-pool rebuilds
//! that follow them (see OBSERVABILITY.md). Clients read it through the
//! `{"op": "stats"}` control line, answered synchronously (never
//! queued) with either a JSON snapshot (`"format": "json"`, the
//! default) or a Prometheus-style text exposition embedded as one
//! string (`"format": "text"`).

use super::jobs::{kind_name, JobSpec};
use super::json::{report_to_json, Json};
use super::runner::panic_message;
use crate::decompose::{solve_decomposed, solve_decomposed_resumed};
use crate::obs::metrics::MetricsRegistry;
use crate::runtime::cancel::CancelToken;
use crate::runtime::failpoint;
use crate::runtime::pool::WorkerPool;
use crate::screening::checkpoint::{CheckpointConf, CheckpointSink, SolveCheckpoint};
use crate::screening::iaes::{
    solve_sfm_with_screening, IaesEngine, IaesReport, NumericFault,
};
use crate::submodular::Submodular;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where a job's response line goes. Per-connection for socket clients,
/// the shared primary sink (stdout) for stdin jobs.
pub type Sink = Arc<Mutex<Box<dyn Write + Send>>>;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Concurrent solve workers (0 = all available cores).
    pub workers: usize,
    /// Admission-queue capacity; submissions beyond it are rejected.
    pub queue_cap: usize,
    /// Default per-job deadline applied when a request carries no
    /// `deadline_ms` field (`None` = no deadline).
    pub default_deadline_ms: Option<u64>,
    /// Greedy-oracle lanes per worker (1 = sequential oracle). Pooled
    /// passes are bit-identical to sequential, so this only changes
    /// wall clock.
    pub oracle_threads: usize,
    /// Optional unix-socket ingress path.
    pub socket: Option<PathBuf>,
    /// Extra attempts for jobs that end in a contained panic or numeric
    /// fault (`0`, the default, answers on the first failure — the PR-8
    /// behavior). Retry-armed jobs carry an in-memory boundary
    /// checkpoint, so a retried attempt resumes from the last safe
    /// snapshot instead of restarting cold.
    pub retries: usize,
    /// Base backoff before a retry, doubled per attempt and clamped so
    /// the sleep never extends past the job's original admission
    /// deadline.
    pub retry_backoff_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 1,
            queue_cap: 64,
            default_deadline_ms: None,
            oracle_threads: 1,
            socket: None,
            retries: 0,
            retry_backoff_ms: 100,
        }
    }
}

/// An admitted job waiting for a worker.
struct Pending {
    seq: u64,
    id: Json,
    spec: JobSpec,
    /// Absolute deadline, armed at *admission* so queue time counts.
    deadline_at: Option<Instant>,
    /// When the job entered the queue — the worker that dequeues it
    /// reports the difference as `queue_wait_s` (the deadline arms at
    /// admission, so this is the interval already burning it down).
    admitted_at: Instant,
    sink: Sink,
}

/// State shared between the submitters and the solve workers.
struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    available: Condvar,
    cap: usize,
    shutdown: AtomicBool,
    seq: AtomicU64,
    default_sink: Sink,
    default_deadline_ms: Option<u64>,
    oracle_threads: usize,
    retries: usize,
    retry_backoff_ms: u64,
    /// Immutable-oracle cache for monolithic jobs, keyed by workload
    /// spec. Oracles are plain data (`Submodular: Sync`), so sharing one
    /// across workers never affects a trajectory.
    cache: Mutex<HashMap<String, Arc<dyn Submodular + Send + Sync>>>,
    /// Serve telemetry. Lives here — not in any worker — so counts are
    /// reset-safe across contained job panics and pool rebuilds: a
    /// worker that unwinds mid-job never holds the only reference.
    metrics: MetricsRegistry,
}

/// Poison-adopting lock: serve state under any mutex is either a plain
/// collection mutated through `&mut` methods (queue, cache) or a sink —
/// a panic elsewhere on the holding thread cannot leave them mid-update.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Cheap cloneable submission handle (used by ingress threads).
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<Shared>,
}

/// The resident service: worker threads plus a [`ServeHandle`].
pub struct ServeCore {
    handle: ServeHandle,
    workers: Vec<JoinHandle<()>>,
}

impl ServeCore {
    /// Start the service with `opts.workers` solve workers (0 = all
    /// cores) writing responses to `sink`.
    pub fn start(opts: &ServeOptions, sink: Box<dyn Write + Send>) -> ServeCore {
        let workers = match opts.workers {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            w => w,
        };
        ServeCore::start_inner(opts, sink, workers)
    }

    /// Admission-control test hook: the same state machine with *no*
    /// worker threads, so the queue fills deterministically.
    pub fn start_without_workers(opts: &ServeOptions, sink: Box<dyn Write + Send>) -> ServeCore {
        ServeCore::start_inner(opts, sink, 0)
    }

    fn start_inner(opts: &ServeOptions, sink: Box<dyn Write + Send>, workers: usize) -> ServeCore {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            cap: opts.queue_cap.max(1),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            default_sink: Arc::new(Mutex::new(sink)),
            default_deadline_ms: opts.default_deadline_ms,
            oracle_threads: opts.oracle_threads.max(1),
            retries: opts.retries,
            retry_backoff_ms: opts.retry_backoff_ms,
            cache: Mutex::new(HashMap::new()),
            metrics: MetricsRegistry::new(),
        });
        let workers = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sfm-serve-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning serve worker")
            })
            .collect();
        ServeCore { handle: ServeHandle { shared }, workers }
    }

    /// A cloneable submission handle for additional ingress threads.
    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Submit one request line; the response goes to the primary sink.
    pub fn submit_line(&self, line: &str) {
        self.handle.submit_line(line);
    }

    /// Oracle-cache hits so far (telemetry / test hook).
    pub fn cache_hits(&self) -> u64 {
        self.handle.shared.metrics.cache_hits.get()
    }

    /// Worker oracle-pool rebuilds after contained panics (test hook).
    pub fn pool_rebuilds(&self) -> u64 {
        self.handle.shared.metrics.pool_rebuilds.get()
    }

    /// The serve metrics registry (telemetry / test hook) — the same
    /// snapshot the `{"op": "stats"}` control line serves.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.handle.shared.metrics
    }

    /// Drain the queue, stop the workers, and join them. Every admitted
    /// job still gets a response before this returns.
    pub fn finish(self) {
        self.handle.shared.shutdown.store(true, Ordering::Release);
        self.handle.shared.available.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

impl ServeHandle {
    /// Submit one request line; the response goes to the primary sink.
    pub fn submit_line(&self, line: &str) {
        let sink = Arc::clone(&self.shared.default_sink);
        self.submit_line_with(line, &sink);
    }

    /// Submit one request line, directing the response to `sink`.
    /// Malformed lines and queue-full rejections are answered
    /// synchronously; admitted jobs respond when a worker finishes.
    /// Blank lines are ignored.
    pub fn submit_line_with(&self, line: &str, sink: &Sink) {
        let line = line.trim();
        if line.is_empty() {
            return;
        }
        let seq = self.shared.seq.fetch_add(1, Ordering::Relaxed);
        let m = &self.shared.metrics;
        let parsed = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                m.jobs_invalid.inc();
                let msg = format!("job {seq}: line is not valid JSON: {e:#}");
                reject(sink, &Json::Null, seq, "error", "invalid", msg);
                return;
            }
        };
        // Control lines (`{"op": …}`) are answered synchronously from
        // the registry — they never compete with solves for the queue.
        if parsed.get("op").is_some() {
            self.handle_op(&parsed, seq, sink);
            return;
        }
        let id = parsed.get("id").cloned().unwrap_or(Json::Null);
        let (deadline_ms, rest) = match split_envelope(parsed) {
            Ok(x) => x,
            Err(e) => {
                m.jobs_invalid.inc();
                reject(sink, &id, seq, "error", "invalid", format!("job {seq}: {e:#}"));
                return;
            }
        };
        let spec = match JobSpec::parse(&rest) {
            Ok(s) => s,
            Err(e) => {
                m.jobs_invalid.inc();
                reject(sink, &id, seq, "error", "invalid", format!("job {seq}: {e:#}"));
                return;
            }
        };
        let deadline_ms = deadline_ms.or(self.shared.default_deadline_ms);
        let now = Instant::now();
        let deadline_at = deadline_ms.map(|ms| now + Duration::from_millis(ms));
        let job = Pending {
            seq,
            id: id.clone(),
            spec,
            deadline_at,
            admitted_at: now,
            sink: Arc::clone(sink),
        };
        {
            let mut q = lock(&self.shared.queue);
            if q.len() >= self.shared.cap {
                drop(q);
                m.jobs_rejected.inc();
                let msg = format!(
                    "admission queue full ({} waiting jobs); retry after a response arrives",
                    self.shared.cap
                );
                reject(sink, &id, seq, "rejected", "queue_full", msg);
                return;
            }
            q.push_back(job);
            m.jobs_accepted.inc();
            m.queue_depth.inc();
        }
        self.shared.available.notify_one();
    }

    /// Answer a `{"op": …}` control line. The only operation is
    /// `"stats"`; optional fields are `id` (echoed) and `format`
    /// (`"json"`, the default, or `"text"` for a Prometheus-style
    /// exposition embedded as one string). Unknown ops, fields, and
    /// formats are typed `invalid` errors naming the offender.
    fn handle_op(&self, v: &Json, seq: u64, sink: &Sink) {
        let m = &self.shared.metrics;
        let id = v.get("id").cloned().unwrap_or(Json::Null);
        let fail = |msg: String| {
            m.jobs_invalid.inc();
            reject(sink, &id, seq, "error", "invalid", format!("job {seq}: {msg}"));
        };
        if let Json::Obj(pairs) = v {
            for (k, _) in pairs {
                if !["op", "id", "format"].contains(&k.as_str()) {
                    return fail(format!(
                        "{k}: unknown field (allowed: op, id, format)"
                    ));
                }
            }
        }
        match v.get("op") {
            Some(Json::Str(op)) if op == "stats" => {}
            Some(Json::Str(op)) => {
                return fail(format!("op: unknown operation `{op}` (stats)"));
            }
            Some(other) => {
                return fail(format!("op: expected a string, got {}", kind_name(other)));
            }
            // The dispatcher only routes here when `op` is present; if
            // that ever changes, reject instead of panicking.
            None => return fail("op: missing".to_string()),
        }
        let text = match v.get("format") {
            None => false,
            Some(Json::Str(f)) if f == "json" => false,
            Some(Json::Str(f)) if f == "text" => true,
            Some(Json::Str(f)) => {
                return fail(format!("format: unknown format `{f}` (json|text)"));
            }
            Some(other) => {
                return fail(format!(
                    "format: expected a string, got {}",
                    kind_name(other)
                ));
            }
        };
        // Count the request before snapshotting so the snapshot it
        // returns already reflects it (deterministic for tests).
        m.stats_requests.inc();
        let stats = if text { Json::Str(m.render_text()) } else { m.to_json() };
        write_line(
            sink,
            &Json::obj(vec![
                ("id", id.clone()),
                ("seq", Json::Num(seq as f64)),
                ("status", Json::Str("ok".into())),
                ("stats", stats),
                ("error", Json::Null),
            ]),
        );
    }

    /// Accept request lines on a unix socket; each connection gets its
    /// responses on that same connection. The accept thread is detached
    /// (it lives until the process exits).
    #[cfg(unix)]
    pub fn listen_unix(&self, path: &std::path::Path) -> Result<()> {
        use std::os::unix::net::UnixListener;
        // A stale socket file from a previous run would make bind fail.
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        let handle = self.clone();
        std::thread::Builder::new()
            .name("sfm-serve-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    let Ok(conn) = conn else { continue };
                    let Ok(reader) = conn.try_clone() else { continue };
                    let handle = handle.clone();
                    let _ = std::thread::Builder::new().name("sfm-serve-conn".into()).spawn(
                        move || {
                            use std::io::BufRead;
                            let boxed: Box<dyn Write + Send> = Box::new(conn);
                            let sink: Sink = Arc::new(Mutex::new(boxed));
                            for line in std::io::BufReader::new(reader).lines() {
                                let Ok(line) = line else { break };
                                handle.submit_line_with(&line, &sink);
                            }
                        },
                    );
                }
            })
            .context("spawning unix-socket accept thread")?;
        Ok(())
    }
}

/// Strip the transport-envelope fields (`id`, `deadline_ms`) from a
/// request object so the remainder parses as a plain [`JobSpec`].
fn split_envelope(v: Json) -> Result<(Option<u64>, Json)> {
    match v {
        Json::Obj(pairs) => {
            let mut deadline = None;
            let mut rest = Vec::with_capacity(pairs.len());
            for (k, val) in pairs {
                match k.as_str() {
                    "id" => {}
                    "deadline_ms" => {
                        let ok = matches!(&val, Json::Num(x)
                            if x.is_finite() && *x >= 0.0 && x.fract() == 0.0);
                        if !ok {
                            bail!(
                                "deadline_ms: expected a non-negative integer, got {}",
                                kind_name(&val)
                            );
                        }
                        if let Json::Num(x) = val {
                            deadline = Some(x as u64);
                        }
                    }
                    _ => rest.push((k, val)),
                }
            }
            Ok((deadline, Json::Obj(rest)))
        }
        other => Ok((None, other)),
    }
}

/// Build one response line. `queue_wait_s` is `None` for lines that
/// never reached the admission queue (serialized as `null`).
fn envelope(
    id: &Json,
    seq: u64,
    status: &str,
    report: Json,
    error: Option<(&str, String)>,
    wall_s: f64,
    queue_wait_s: Option<f64>,
) -> Json {
    Json::obj(vec![
        ("id", id.clone()),
        ("seq", Json::Num(seq as f64)),
        ("status", Json::Str(status.to_string())),
        ("report", report),
        (
            "error",
            match error {
                Some((kind, message)) => Json::obj(vec![
                    ("kind", Json::Str(kind.to_string())),
                    ("message", Json::Str(message)),
                ]),
                None => Json::Null,
            },
        ),
        ("wall_s", Json::Num(wall_s)),
        ("queue_wait_s", queue_wait_s.map_or(Json::Null, Json::Num)),
    ])
}

/// Answer a request that never reached a worker (parse failure or
/// queue-full rejection): no report, zero wall time, no queue wait.
fn reject(sink: &Sink, id: &Json, seq: u64, status: &str, kind: &str, msg: String) {
    write_line(sink, &envelope(id, seq, status, Json::Null, Some((kind, msg)), 0.0, None));
}

/// Emit one response line (newline-delimited JSON) and flush, so a
/// client blocked on the reply never waits on our buffering.
fn write_line(sink: &Sink, env: &Json) {
    let mut s = lock(sink);
    if writeln!(s, "{}", env.to_string()).is_ok() {
        let _ = s.flush();
    }
}

/// Per-worker greedy-oracle pool (`None` when the oracle is sequential,
/// or when the pool threads cannot be spawned — jobs then run with
/// in-thread oracle evaluation instead of taking the worker down).
fn make_pool(oracle_threads: usize) -> Option<Arc<WorkerPool>> {
    (oracle_threads > 1)
        .then(|| WorkerPool::try_new(oracle_threads - 1).ok().map(Arc::new))
        .flatten()
}

fn worker_loop(shared: &Arc<Shared>) {
    let mut pool = make_pool(shared.oracle_threads);
    loop {
        let job = {
            let mut q = lock(&shared.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.available.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(job) = job else { return };
        serve_one(shared, &job, &mut pool);
        // Answered (serve_one always writes a response line) — the
        // depth gauge covers queued *and* in-flight jobs.
        shared.metrics.queue_depth.dec();
    }
}

/// Budgeted backoff before a retry: `retry_backoff_ms · 2^(attempt-1)`,
/// clamped so the sleep can never extend past the job's *original*
/// admission deadline — a retry may burn whatever budget the failed
/// attempt left, never grow it.
fn retry_backoff(shared: &Shared, job: &Pending, attempt: usize) {
    let shift = attempt.saturating_sub(1).min(16) as u32;
    let ms = shared.retry_backoff_ms.saturating_mul(1u64 << shift);
    let mut delay = Duration::from_millis(ms);
    if let Some(d) = job.deadline_at {
        delay = delay.min(d.saturating_duration_since(Instant::now()));
    }
    if !delay.is_zero() {
        std::thread::sleep(delay);
    }
}

/// Run one admitted job and write its response. This is the containment
/// boundary: panics, numeric faults, and deadline expiries all end here
/// as structured responses — never as a dead worker. With `--retries`,
/// a panicked or numeric-faulted attempt is re-admitted from the job's
/// last in-memory boundary checkpoint (cold when none was captured yet)
/// after a budgeted backoff; `wall_s` covers every attempt, and the
/// deadline keeps counting from the original admission.
fn serve_one(shared: &Shared, job: &Pending, pool: &mut Option<Arc<WorkerPool>>) {
    let m = &shared.metrics;
    let t0 = Instant::now();
    let queue_wait_s = (t0 - job.admitted_at).as_secs_f64();
    m.queue_wait.observe(queue_wait_s);
    // Per-job in-memory checkpoint slot, armed only when retries are
    // configured: a zero-retry service runs exactly the PR-8 path.
    let sink = (shared.retries > 0).then(CheckpointSink::in_memory);
    let mut attempt = 0usize;
    let env = loop {
        let resume = if attempt > 0 {
            sink.as_ref().and_then(CheckpointSink::latest)
        } else {
            None
        };
        if resume.is_some() {
            m.resumes.inc();
        }
        let ckpt = sink.clone().map(|s| CheckpointConf::new(s, 1));
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            failpoint::hit("serve-job");
            run_job(shared, job, pool.clone(), ckpt, resume)
        }));
        let wall_s = t0.elapsed().as_secs_f64();
        match outcome {
            Ok(Ok(report)) => {
                let status = if report.cancel_reason.is_some() || !report.converged {
                    "partial"
                } else {
                    "ok"
                };
                if status == "ok" {
                    m.jobs_ok.inc();
                    m.wall_ok.observe(wall_s);
                } else {
                    m.jobs_partial.inc();
                    m.wall_partial.observe(wall_s);
                }
                let rj = report_to_json(&report, job.spec.opts.record_history);
                break envelope(
                    &job.id,
                    job.seq,
                    status,
                    rj,
                    None,
                    wall_s,
                    Some(queue_wait_s),
                );
            }
            Ok(Err(err)) => {
                let numeric = err.downcast_ref::<NumericFault>().is_some();
                if numeric {
                    m.jobs_numeric_faulted.inc();
                }
                if numeric && attempt < shared.retries {
                    attempt += 1;
                    m.jobs_retried.inc();
                    retry_backoff(shared, job, attempt);
                    continue;
                }
                let kind = if numeric { "numeric" } else { "error" };
                m.jobs_error.inc();
                m.wall_error.observe(wall_s);
                let msg = format!("{err:#}");
                break envelope(
                    &job.id,
                    job.seq,
                    "error",
                    Json::Null,
                    Some((kind, msg)),
                    wall_s,
                    Some(queue_wait_s),
                );
            }
            Err(payload) => {
                // Contained job panic. The solve may have unwound through
                // a pooled oracle pass, so rebuild this worker's pool
                // rather than reason about what state the unwind left it
                // in — a retried attempt must start from a sound pool.
                // The registry lives in `shared`, not in this worker, so
                // every count (including this one) survives the rebuild.
                if pool.is_some() {
                    *pool = make_pool(shared.oracle_threads);
                    m.pool_rebuilds.inc();
                }
                m.jobs_panicked.inc();
                if attempt < shared.retries {
                    attempt += 1;
                    m.jobs_retried.inc();
                    retry_backoff(shared, job, attempt);
                    continue;
                }
                m.jobs_error.inc();
                m.wall_error.observe(wall_s);
                let msg = format!("job panicked: {}", panic_message(payload.as_ref()));
                break envelope(
                    &job.id,
                    job.seq,
                    "error",
                    Json::Null,
                    Some(("panic", msg)),
                    wall_s,
                    Some(queue_wait_s),
                );
            }
        }
    };
    if let Some(s) = &sink {
        m.checkpoints_written.add(s.written());
    }
    write_line(&job.sink, &env);
}

/// Execute the solve for one job, arming the cancel token and (for
/// monolithic jobs) the shared-instance cache and the worker's oracle
/// pool. Decomposed jobs go through the block solver — it owns its own
/// parallelism and instances are not cached. `ckpt` attaches boundary
/// checkpointing; `resume` restarts the solve from a snapshot instead
/// of cold (both `None` outside retry-armed services).
fn run_job(
    shared: &Shared,
    job: &Pending,
    pool: Option<Arc<WorkerPool>>,
    ckpt: Option<CheckpointConf>,
    resume: Option<SolveCheckpoint>,
) -> Result<IaesReport> {
    let mut spec = job.spec.clone();
    // Retries re-arm from the job's *original* admission instant: a
    // resumed attempt inherits whatever deadline budget the failed
    // attempt left, never a fresh window.
    spec.opts.cancel = job.deadline_at.map(CancelToken::with_deadline_at);
    spec.opts.checkpoint = ckpt;
    if let Some(dopts) = spec.decompose {
        let f = spec.workload.build_decomposed()?;
        return match resume {
            Some(ck) => solve_decomposed_resumed(&f, &spec.opts, dopts, ck),
            None => solve_decomposed(&f, &spec.opts, dopts),
        };
    }
    spec.opts.oracle_pool = pool;
    let key = spec.workload.cache_key();
    let cached = lock(&shared.cache).get(&key).cloned();
    let f = match cached {
        Some(f) => {
            shared.metrics.cache_hits.inc();
            f
        }
        None => {
            let f = spec.workload.build_shared()?;
            lock(&shared.cache).insert(key, Arc::clone(&f));
            f
        }
    };
    match resume {
        Some(ck) => IaesEngine::new(f.as_ref(), spec.opts.clone()).resume_from(ck)?.run(),
        None => solve_sfm_with_screening(f.as_ref(), &spec.opts),
    }
}

/// Run the resident service: responses to stdout, requests from stdin
/// (newline-delimited) and, when `opts.socket` is set, from a unix
/// socket. Returns after stdin reaches EOF and every admitted job has
/// been answered.
pub fn serve(opts: &ServeOptions) -> Result<()> {
    let core = ServeCore::start(opts, Box::new(std::io::stdout()));
    if let Some(path) = &opts.socket {
        #[cfg(unix)]
        core.handle().listen_unix(path)?;
        #[cfg(not(unix))]
        bail!("--socket {} requires a unix platform", path.display());
    }
    for line in std::io::stdin().lines() {
        let line = line.context("reading stdin")?;
        core.submit_line(&line);
    }
    core.finish();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared capture buffer usable as a service sink.
    #[derive(Clone, Default)]
    struct Buf(Arc<Mutex<Vec<u8>>>);

    impl Write for Buf {
        fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
            lock(&self.0).extend_from_slice(data);
            Ok(data.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    impl Buf {
        fn lines(&self) -> Vec<Json> {
            let raw = String::from_utf8(lock(&self.0).clone()).unwrap();
            raw.lines().map(|l| Json::parse(l).expect("response line parses")).collect()
        }

        /// Complete response lines so far — safe to poll while workers
        /// are still writing (a line is complete once its newline
        /// lands; [`Self::lines`] may race a partially written line).
        fn newlines(&self) -> usize {
            lock(&self.0).iter().filter(|&&b| b == b'\n').count()
        }
    }

    fn field<'a>(env: &'a Json, key: &str) -> &'a Json {
        env.get(key).unwrap_or_else(|| panic!("response missing `{key}`"))
    }

    fn status(env: &Json) -> String {
        field(env, "status").as_str().unwrap().to_string()
    }

    fn error_kind(env: &Json) -> String {
        field(env, "error").get("kind").unwrap().as_str().unwrap().to_string()
    }

    const IWATA_JOB: &str = r#"{"id": "j1", "workload": {"kind": "iwata", "p": 24}}"#;

    #[test]
    fn ok_job_round_trips_with_id_and_report() {
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(IWATA_JOB);
        core.finish();
        let lines = buf.lines();
        assert_eq!(lines.len(), 1);
        let env = &lines[0];
        assert_eq!(status(env), "ok");
        assert_eq!(field(env, "id").as_str().unwrap(), "j1");
        assert!(matches!(field(env, "error"), Json::Null));
        let report = field(env, "report");
        assert_eq!(report.get("converged").unwrap().as_bool(), Some(true));
        assert!(matches!(report.get("cancel_reason").unwrap(), Json::Null));
    }

    #[test]
    fn blank_lines_are_ignored_and_malformed_lines_answered() {
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line("");
        core.submit_line("   ");
        core.submit_line("{not json");
        core.submit_line(r#"{"workload": {"kind": "iwata", "p": 24}, "epz": 1.0}"#);
        core.finish();
        let lines = buf.lines();
        assert_eq!(lines.len(), 2);
        for env in &lines {
            assert_eq!(status(env), "error");
            assert_eq!(error_kind(env), "invalid");
            assert!(matches!(field(env, "report"), Json::Null));
        }
        // The field error names the offender and the job sequence.
        let msg =
            field(&lines[1], "error").get("message").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("epz"), "{msg}");
        assert!(msg.contains("job "), "{msg}");
    }

    #[test]
    fn zero_deadline_yields_partial_status() {
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(
            r#"{"id": 7, "deadline_ms": 0, "workload": {"kind": "iwata", "p": 24}}"#,
        );
        core.finish();
        let lines = buf.lines();
        assert_eq!(lines.len(), 1);
        let env = &lines[0];
        assert_eq!(status(env), "partial");
        assert_eq!(field(env, "id").as_num().unwrap(), 7.0);
        let report = field(env, "report");
        assert_eq!(
            report.get("cancel_reason").unwrap().as_str().unwrap(),
            "deadline"
        );
        assert_eq!(report.get("converged").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn bad_deadline_is_an_invalid_request() {
        let buf = Buf::default();
        let core =
            ServeCore::start_without_workers(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(r#"{"deadline_ms": -5, "workload": {"kind": "iwata", "p": 24}}"#);
        core.submit_line(r#"{"deadline_ms": "soon", "workload": {"kind": "iwata", "p": 24}}"#);
        let lines = buf.lines();
        assert_eq!(lines.len(), 2);
        for env in &lines {
            assert_eq!(error_kind(env), "invalid");
            let msg = field(env, "error").get("message").unwrap().as_str().unwrap().to_string();
            assert!(msg.contains("deadline_ms"), "{msg}");
        }
        core.finish();
    }

    #[test]
    fn overflowing_the_queue_rejects_with_queue_full() {
        let buf = Buf::default();
        let opts = ServeOptions { queue_cap: 2, ..Default::default() };
        // No workers: admitted jobs stay queued, so the third submission
        // must overflow deterministically.
        let core = ServeCore::start_without_workers(&opts, Box::new(buf.clone()));
        core.submit_line(IWATA_JOB);
        core.submit_line(IWATA_JOB);
        core.submit_line(r#"{"id": "reject-me", "workload": {"kind": "iwata", "p": 24}}"#);
        let lines = buf.lines();
        assert_eq!(lines.len(), 1, "only the rejection responds synchronously");
        let env = &lines[0];
        assert_eq!(status(env), "rejected");
        assert_eq!(error_kind(env), "queue_full");
        assert_eq!(field(env, "id").as_str().unwrap(), "reject-me");
        core.finish();
    }

    #[test]
    fn cache_hit_counter_counts_rebuild_free_reuse() {
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(IWATA_JOB);
        core.submit_line(IWATA_JOB);
        core.submit_line(r#"{"workload": {"kind": "iwata", "p": 30}}"#);
        // Wait for all three responses before reading the counter.
        let deadline = Instant::now() + Duration::from_secs(30);
        while buf.newlines() < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(buf.lines().len(), 3);
        // Two identical specs share one build; the p=30 spec is a miss.
        assert_eq!(core.cache_hits(), 1);
        assert_eq!(core.pool_rebuilds(), 0);
        core.finish();
    }

    #[test]
    fn responses_carry_queue_wait_alongside_wall_time() {
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(IWATA_JOB);
        core.submit_line("{not json");
        core.finish();
        let lines = buf.lines();
        assert_eq!(lines.len(), 2);
        for env in &lines {
            assert!(env.get("queue_wait_s").is_some(), "queue_wait_s missing");
        }
        let solved = lines.iter().find(|e| status(e) == "ok").unwrap();
        let wait = field(solved, "queue_wait_s").as_num().unwrap();
        assert!(wait.is_finite() && wait >= 0.0, "queue_wait_s = {wait}");
        assert!(field(solved, "wall_s").as_num().unwrap() >= 0.0);
        // A line that never reached the queue has no queue wait.
        let rejected = lines.iter().find(|e| status(e) == "error").unwrap();
        assert!(matches!(field(rejected, "queue_wait_s"), Json::Null));
    }

    #[test]
    fn stats_op_round_trips_in_json_and_text() {
        use crate::obs::metrics::validate_exposition;
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        // Scripted mix: one ok, one partial (zero deadline), one invalid.
        core.submit_line(IWATA_JOB);
        core.submit_line(
            r#"{"deadline_ms": 0, "workload": {"kind": "iwata", "p": 24}}"#,
        );
        core.submit_line("{not json");
        // Wait until both admitted jobs are fully answered (the depth
        // gauge drops after the response line is written).
        let deadline = Instant::now() + Duration::from_secs(30);
        while (buf.newlines() < 3 || core.metrics().queue_depth.get() != 0)
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        core.submit_line(r#"{"op": "stats", "id": "s1"}"#);
        core.submit_line(r#"{"op": "stats", "id": "s2", "format": "text"}"#);
        core.finish();
        let lines = buf.lines();
        assert_eq!(lines.len(), 5);
        let json_stats = field(by_id(&lines, "s1"), "stats");
        let jobs = json_stats.get("jobs").unwrap();
        assert_eq!(jobs.get("accepted").unwrap().as_num(), Some(2.0));
        assert_eq!(jobs.get("ok").unwrap().as_num(), Some(1.0));
        assert_eq!(jobs.get("partial").unwrap().as_num(), Some(1.0));
        assert_eq!(jobs.get("invalid").unwrap().as_num(), Some(1.0));
        assert_eq!(jobs.get("rejected").unwrap().as_num(), Some(0.0));
        assert_eq!(json_stats.get("queue_depth").unwrap().as_num(), Some(0.0));
        assert_eq!(json_stats.get("stats_requests").unwrap().as_num(), Some(1.0));
        // Histograms carry the same mix: one ok wall sample, one partial,
        // two queue waits.
        let wall = json_stats.get("wall_s").unwrap();
        assert_eq!(wall.get("ok").unwrap().get("count").unwrap().as_num(), Some(1.0));
        assert_eq!(
            wall.get("partial").unwrap().get("count").unwrap().as_num(),
            Some(1.0)
        );
        assert_eq!(wall.get("error").unwrap().get("count").unwrap().as_num(), Some(0.0));
        assert_eq!(
            json_stats.get("queue_wait_s").unwrap().get("count").unwrap().as_num(),
            Some(2.0)
        );
        // The text form is a valid Prometheus exposition reflecting the
        // same counts.
        let text = field(by_id(&lines, "s2"), "stats").as_str().unwrap().to_string();
        let samples = validate_exposition(&text).expect("exposition validates");
        assert!(samples > 10, "only {samples} samples");
        assert!(text.contains("sfm_serve_jobs_total{status=\"ok\"} 1"), "{text}");
        assert!(text.contains("sfm_serve_jobs_total{status=\"partial\"} 1"), "{text}");
        assert!(text.contains("sfm_serve_rejects_total{kind=\"invalid\"} 1"), "{text}");
        assert!(text.contains("sfm_serve_stats_requests_total 2"), "{text}");
    }

    #[test]
    fn malformed_op_lines_are_typed_errors_naming_the_field() {
        let buf = Buf::default();
        let core =
            ServeCore::start_without_workers(&ServeOptions::default(), Box::new(buf.clone()));
        let cases = [
            (r#"{"op": "frobnicate"}"#, "op"),
            (r#"{"op": 7}"#, "op"),
            (r#"{"op": "stats", "verbose": true}"#, "verbose"),
            (r#"{"op": "stats", "format": "xml"}"#, "format"),
            (r#"{"op": "stats", "format": 3}"#, "format"),
        ];
        for (line, _) in cases {
            core.submit_line(line);
        }
        let lines = buf.lines();
        assert_eq!(lines.len(), cases.len());
        for (env, (line, needle)) in lines.iter().zip(cases) {
            assert_eq!(status(env), "error", "{line}");
            assert_eq!(error_kind(env), "invalid", "{line}");
            let msg = field(env, "error").get("message").unwrap().as_str().unwrap();
            assert!(msg.contains(needle), "`{line}`: got `{msg}`, wanted `{needle}`");
        }
        // None of the malformed control lines counted as a served stats
        // request, but each counted as an invalid submission.
        assert_eq!(core.metrics().stats_requests.get(), 0);
        assert_eq!(core.metrics().jobs_invalid.get(), cases.len() as u64);
        core.finish();
    }

    fn by_id<'a>(lines: &'a [Json], id: &str) -> &'a Json {
        lines
            .iter()
            .find(|e| e.get("id").and_then(Json::as_str) == Some(id))
            .unwrap_or_else(|| panic!("no response with id `{id}`"))
    }

    #[test]
    fn served_solve_matches_direct_solve_bitwise() {
        let direct = {
            let f = crate::submodular::iwata::IwataFn::new(32);
            solve_sfm_with_screening(&f, &crate::screening::iaes::IaesOptions::default()).unwrap()
        };
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        core.submit_line(r#"{"workload": {"kind": "iwata", "p": 32}}"#);
        core.finish();
        let lines = buf.lines();
        let report = field(&lines[0], "report");
        assert_eq!(
            report.get("minimum").unwrap().as_num().unwrap().to_bits(),
            direct.minimum.to_bits()
        );
        let ids: Vec<f64> = report
            .get("minimizer")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_num().unwrap())
            .collect();
        let expect: Vec<f64> = direct.minimizer.iter().map(|&i| i as f64).collect();
        assert_eq!(ids, expect);
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_round_trip() {
        use std::io::{BufRead, BufReader};
        use std::os::unix::net::UnixStream;
        let buf = Buf::default();
        let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
        let dir = std::env::temp_dir().join(format!("sfm-serve-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.sock");
        core.handle().listen_unix(&path).unwrap();
        let mut conn = UnixStream::connect(&path).unwrap();
        writeln!(conn, r#"{{"id": "sock", "workload": {{"kind": "iwata", "p": 24}}}}"#).unwrap();
        let mut line = String::new();
        BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
        let env = Json::parse(&line).unwrap();
        assert_eq!(status(&env), "ok");
        assert_eq!(field(&env, "id").as_str().unwrap(), "sock");
        // Socket responses never leak onto the primary sink.
        assert!(buf.lines().is_empty());
        drop(conn);
        core.finish();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
