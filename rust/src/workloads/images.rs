//! Synthetic image-segmentation instances — the §4.2 workload.
//!
//! The paper uses five GrabCut instances from [22] (shipped only in its
//! supplement). We substitute synthetic scenes that preserve the structure
//! the experiment probes (DESIGN.md §Substitutions): a *small* smooth
//! foreground blob (so AES alone buys little — the paper's own
//! observation), a large textured background (IES does the heavy lifting),
//! GMM unaries fit on seed strips, and the paper's 8-neighbor pairwise
//! weights `d(i,j) = exp(−‖x_i − x_j‖²)`.

use super::gmm::{unary_potentials, Gmm2};
use super::grid::eight_neighbor_edges;
use crate::rng::Pcg64;
use crate::submodular::cut::CutFn;

/// Parameters of one synthetic scene.
#[derive(Clone, Copy, Debug)]
pub struct ImageParams {
    /// Height in pixels.
    pub h: usize,
    /// Width in pixels.
    pub w: usize,
    /// Foreground ellipse semi-axes as fractions of (h, w).
    pub fg_a: f64,
    /// Second semi-axis fraction.
    pub fg_b: f64,
    /// Foreground/background mean intensities.
    pub fg_mean: f64,
    /// Background mean intensity.
    pub bg_mean: f64,
    /// Intensity noise std.
    pub noise: f64,
    /// Background texture amplitude (low-frequency sinusoid).
    pub texture: f64,
    /// Unary strength β.
    pub beta: f64,
    /// Seed.
    pub seed: u64,
}

/// A generated scene + its segmentation objective ingredients.
#[derive(Clone, Debug)]
pub struct ImageInstance {
    /// Human-readable name (`image1`..`image5`).
    pub name: String,
    /// Parameters.
    pub params: ImageParams,
    /// Grayscale intensities, row-major `h × w`.
    pub pixels: Vec<f64>,
    /// Ground-truth foreground mask.
    pub truth: Vec<bool>,
    /// GMM unary potentials.
    pub unary: Vec<f64>,
    /// Undirected weighted edges `(i, j, exp(−(x_i−x_j)²))`.
    pub edges: Vec<(usize, usize, f64)>,
}

impl ImageInstance {
    /// Generate a scene.
    pub fn generate(name: &str, params: ImageParams) -> Self {
        let ImageParams { h, w, .. } = params;
        let p = h * w;
        let mut rng = Pcg64::new(params.seed, 0x1337_4242);
        let cy = h as f64 / 2.0;
        let cx = w as f64 / 2.0;
        let ay = params.fg_a * h as f64;
        let ax = params.fg_b * w as f64;

        let mut pixels = vec![0.0; p];
        let mut truth = vec![false; p];
        for r in 0..h {
            for c in 0..w {
                let i = r * w + c;
                let dy = (r as f64 - cy) / ay;
                let dx = (c as f64 - cx) / ax;
                let inside = dy * dy + dx * dx <= 1.0;
                truth[i] = inside;
                let base = if inside { params.fg_mean } else { params.bg_mean };
                let tex = if inside {
                    0.0
                } else {
                    params.texture
                        * ((r as f64 * 0.37).sin() * (c as f64 * 0.23).cos())
                };
                pixels[i] = (base + tex + rng.normal_ms(0.0, params.noise))
                    .clamp(0.0, 1.0);
            }
        }

        // Seed strips: center rows of the blob for FG, image border for BG
        // (mimicking GrabCut's user strokes).
        let fg_seeds: Vec<f64> = (0..p)
            .filter(|&i| truth[i])
            .filter(|&i| {
                let r = i / w;
                (r as f64 - cy).abs() < ay * 0.4
            })
            .map(|i| pixels[i])
            .collect();
        let bg_seeds: Vec<f64> = (0..p)
            .filter(|&i| {
                let r = i / w;
                let c = i % w;
                r < 2 || c < 2 || r >= h - 2 || c >= w - 2
            })
            .map(|i| pixels[i])
            .collect();
        let fg_model = Gmm2::fit(&fg_seeds, 25);
        let bg_model = Gmm2::fit(&bg_seeds, 25);
        let unary = unary_potentials(&pixels, &fg_model, &bg_model, params.beta);

        let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
            .into_iter()
            .map(|(i, j)| {
                let d = pixels[i] - pixels[j];
                (i, j, (-(d * d)).exp())
            })
            .collect();

        ImageInstance {
            name: name.to_string(),
            params,
            pixels,
            truth,
            unary,
            edges,
        }
    }

    /// Number of pixels.
    pub fn num_pixels(&self) -> usize {
        self.pixels.len()
    }

    /// Number of undirected 8-neighbor edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// The SFM objective `F(A) = u(A) + Σ_{i∈A, j∉A} d(i,j)`.
    pub fn cut_fn(&self) -> CutFn {
        CutFn::from_edges(self.num_pixels(), &self.edges, self.unary.clone())
    }

    /// The same objective as [`cut_fn`](Self::cut_fn), decomposed into
    /// row/column/diagonal chain components plus the modular unary term
    /// — the §4.2 workload for the block-parallel prox solver.
    pub fn cut_decomposition(&self) -> anyhow::Result<crate::decompose::DecomposableFn> {
        crate::decompose::builders::grid_cut_components(
            self.params.h,
            self.params.w,
            &self.edges,
            self.unary.clone(),
        )
    }

    /// Intersection-over-union of `a_star` with the generating mask.
    pub fn iou(&self, a_star: &[usize]) -> f64 {
        let mut in_a = vec![false; self.num_pixels()];
        for &i in a_star {
            in_a[i] = true;
        }
        let mut inter = 0usize;
        let mut union = 0usize;
        for i in 0..self.num_pixels() {
            if in_a[i] && self.truth[i] {
                inter += 1;
            }
            if in_a[i] || self.truth[i] {
                union += 1;
            }
        }
        if union == 0 {
            1.0
        } else {
            inter as f64 / union as f64
        }
    }
}

/// The five benchmark scenes, scaled by `scale` (1.0 ≈ 2–4k pixels;
/// the paper's originals are 26k–60k — use `scale ≈ 4` to match).
pub fn benchmark_suite(scale: f64) -> Vec<ImageInstance> {
    let s = |x: usize| ((x as f64 * scale).round() as usize).max(8);
    let specs: [(&str, usize, usize, f64, f64, u64); 5] = [
        ("image1", 56, 50, 0.28, 0.22, 101),
        ("image2", 41, 36, 0.33, 0.30, 102),
        ("image3", 57, 50, 0.22, 0.18, 103),
        ("image4", 61, 55, 0.30, 0.26, 104),
        ("image5", 53, 48, 0.26, 0.24, 105),
    ];
    specs
        .iter()
        .map(|&(name, h, w, fa, fb, seed)| {
            ImageInstance::generate(
                name,
                ImageParams {
                    h: s(h),
                    w: s(w),
                    fg_a: fa,
                    fg_b: fb,
                    fg_mean: 0.75,
                    bg_mean: 0.30,
                    noise: 0.06,
                    texture: 0.08,
                    beta: 0.35,
                    seed,
                },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions};

    fn small() -> ImageInstance {
        ImageInstance::generate(
            "test",
            ImageParams {
                h: 18,
                w: 16,
                fg_a: 0.3,
                fg_b: 0.25,
                fg_mean: 0.75,
                bg_mean: 0.3,
                noise: 0.05,
                texture: 0.05,
                beta: 0.35,
                seed: 9,
            },
        )
    }

    #[test]
    fn scene_structure() {
        let img = small();
        assert_eq!(img.num_pixels(), 18 * 16);
        let fg = img.truth.iter().filter(|&&b| b).count();
        // Small foreground, as in the paper's observation about AES.
        assert!(fg > 0 && fg < img.num_pixels() / 3, "fg = {fg}");
        assert!(img.edges.iter().all(|&(_, _, w)| (0.0..=1.0).contains(&w)));
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.unary, b.unary);
    }

    #[test]
    fn segmentation_recovers_blob() {
        let img = small();
        let f = img.cut_fn();
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let iou = img.iou(&report.minimizer);
        assert!(iou > 0.6, "IoU only {iou}");
    }

    #[test]
    fn benchmark_suite_names_and_sizes() {
        let suite = benchmark_suite(0.5);
        assert_eq!(suite.len(), 5);
        assert_eq!(suite[0].name, "image1");
        // Edge/pixel ratio close to 4 (8-neighbor interior).
        for img in &suite {
            let r = img.num_edges() as f64 / img.num_pixels() as f64;
            assert!(r > 3.4 && r < 4.0, "{}: ratio {r}", img.name);
        }
    }
}
