//! The two-moons dataset of §4.1.
//!
//! Each point is `x = c_i + γ · [cos θ_i, sin θ_i]` with `c₁ = [−0.5, 1]`,
//! `c₂ = [0.5, −1]`, `γ ~ N(2, 0.5²)`, `θ₁ ~ U[−π/2, π/2]`,
//! `θ₂ ~ U[π/2, 3π/2]`; the two semicircles are sampled with equal
//! probability. `p₀ = 16` random points are labeled (positive iff from the
//! first semicircle).
//!
//! The SFM objective is smoothness + labels:
//! `F(A) = S(A, V∖A) − Σ_{j∈A} log η_j − Σ_{j∈V∖A} log(1−η_j)`
//! where `η_j ∈ {δ, ½, 1−δ}` encodes the labels and `S` is either the GP
//! mutual information (paper-exact; [`crate::submodular::gaussian_mi`]) or
//! the Gaussian-kernel cut (fast substitute;
//! [`crate::submodular::kernel_cut`]). The modular part reduces (up to a
//! constant) to `m_j = −log η_j + log(1 − η_j)`.

use crate::rng::Pcg64;
use crate::submodular::cut::CutFn;
use crate::submodular::gaussian_mi::GaussianMiFn;
use crate::submodular::kernel_cut::KernelCutFn;
use std::f64::consts::PI;

/// Generation parameters (defaults = the paper's).
#[derive(Clone, Copy, Debug)]
pub struct TwoMoonsParams {
    /// Number of points `p`.
    pub p: usize,
    /// Number of labeled points `p₀`.
    pub labeled: usize,
    /// Gaussian-kernel bandwidth `α` (paper: 1.5).
    pub alpha: f64,
    /// Radius mean and std (`γ ~ N(mean, std²)`; paper: 2, 0.5).
    pub radius_mean: f64,
    /// Radius std.
    pub radius_std: f64,
    /// Label confidence `δ`: labeled η = 1−δ or δ.
    pub delta: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for TwoMoonsParams {
    fn default() -> Self {
        TwoMoonsParams {
            p: 400,
            labeled: 16,
            alpha: 1.5,
            radius_mean: 2.0,
            radius_std: 0.5,
            delta: 1e-9,
            seed: 2018,
        }
    }
}

/// A generated two-moons instance.
#[derive(Clone, Debug)]
pub struct TwoMoons {
    /// Parameters used.
    pub params: TwoMoonsParams,
    /// Point coordinates.
    pub points: Vec<[f64; 2]>,
    /// True moon of each point (0 or 1).
    pub moon: Vec<u8>,
    /// Revealed labels: `Some(true)` = positive (moon 0).
    pub labels: Vec<Option<bool>>,
    /// Modular label potentials `m_j = −log η_j + log(1−η_j)`.
    pub unary: Vec<f64>,
}

impl TwoMoons {
    /// Generate an instance.
    pub fn generate(params: TwoMoonsParams) -> Self {
        let mut rng = Pcg64::new(params.seed, 0x7700_1122);
        let p = params.p;
        let c = [[-0.5, 1.0], [0.5, -1.0]];
        let mut points = Vec::with_capacity(p);
        let mut moon = Vec::with_capacity(p);
        for _ in 0..p {
            let m = usize::from(rng.bernoulli(0.5));
            let gamma = rng.normal_ms(params.radius_mean, params.radius_std);
            let theta = if m == 0 {
                rng.uniform(-PI / 2.0, PI / 2.0)
            } else {
                rng.uniform(PI / 2.0, 3.0 * PI / 2.0)
            };
            points.push([
                c[m][0] + gamma * theta.cos(),
                c[m][1] + gamma * theta.sin(),
            ]);
            moon.push(m as u8);
        }
        let mut labels = vec![None; p];
        for &i in &rng.sample_indices(p, params.labeled.min(p)) {
            labels[i] = Some(moon[i] == 0);
        }
        let unary = labels
            .iter()
            .map(|l| {
                let eta = match l {
                    Some(true) => 1.0 - params.delta,
                    Some(false) => params.delta,
                    None => 0.5,
                };
                -(eta as f64).ln() + (1.0 - eta).ln()
            })
            .collect();
        TwoMoons { params, points, moon, labels, unary }
    }

    /// Dense Gaussian similarity matrix `exp(−α‖xi−xj‖²)` (zero diagonal).
    pub fn affinity(&self) -> Vec<f64> {
        let p = self.points.len();
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let dx = self.points[i][0] - self.points[j][0];
                let dy = self.points[i][1] - self.points[j][1];
                let v = (-self.params.alpha * (dx * dx + dy * dy)).exp();
                k[i * p + j] = v;
                k[j * p + i] = v;
            }
        }
        k
    }

    /// Fast objective: Gaussian-kernel cut + label unaries.
    pub fn kernel_cut(&self) -> KernelCutFn {
        KernelCutFn::new(self.points.len(), self.affinity(), self.unary.clone())
    }

    /// Fast objective built from an externally computed affinity matrix
    /// (e.g. the AOT-compiled Pallas affinity kernel via PJRT).
    pub fn kernel_cut_with_affinity(&self, affinity: Vec<f64>) -> KernelCutFn {
        KernelCutFn::new(self.points.len(), affinity, self.unary.clone())
    }

    /// Default benchmark objective: k-nearest-neighbor Gaussian-kernel
    /// cut + label unaries. The kNN sparsification keeps per-point degree
    /// constant across `p`, so the label anchors stay comparable to the
    /// smoothness term at every size — the dense cut degenerates for
    /// large `p` (the cut mass grows O(p²) while the 16 labels are fixed),
    /// whereas the paper's mutual-information objective does not. See
    /// DESIGN.md §Substitutions.
    pub fn knn_cut(&self, k: usize, scale: f64) -> CutFn {
        let p = self.points.len();
        CutFn::from_edges(p, &self.knn_edges(k, scale), self.unary.clone())
    }

    /// The weighted edge list of [`knn_cut`](Self::knn_cut) (mutualized
    /// kNN, Gaussian weights) — shared by the monolithic cut and its
    /// star decomposition so both describe the *same* objective.
    pub fn knn_edges(&self, k: usize, scale: f64) -> Vec<(usize, usize, f64)> {
        let p = self.points.len();
        let mut edge_set = std::collections::HashSet::new();
        let mut dists: Vec<(f64, usize)> = Vec::with_capacity(p);
        for i in 0..p {
            dists.clear();
            for j in 0..p {
                if j != i {
                    let dx = self.points[i][0] - self.points[j][0];
                    let dy = self.points[i][1] - self.points[j][1];
                    dists.push((dx * dx + dy * dy, j));
                }
            }
            let kk = k.min(dists.len());
            dists.select_nth_unstable_by(kk.saturating_sub(1), |a, b| {
                a.0.partial_cmp(&b.0).unwrap()
            });
            for &(_, j) in dists.iter().take(kk) {
                edge_set.insert((i.min(j), i.max(j)));
            }
        }
        // Sort: HashSet iteration order is per-instance random, and the
        // edge order decides CSR adjacency (and so FP summation) order —
        // sorting makes the cut bitwise reproducible across builds and
        // keeps the star decomposition aligned with the monolithic cut.
        let mut edges: Vec<(usize, usize)> = edge_set.into_iter().collect();
        edges.sort_unstable();
        edges
            .into_iter()
            .map(|(i, j)| {
                let dx = self.points[i][0] - self.points[j][0];
                let dy = self.points[i][1] - self.points[j][1];
                let w = scale * (-self.params.alpha * (dx * dx + dy * dy)).exp();
                (i, j, w)
            })
            .collect()
    }

    /// Star decomposition of [`knn_cut`](Self::knn_cut): one per-point
    /// star component per occupied row plus the modular label term —
    /// identical objective, component-parallel prox solves.
    pub fn knn_cut_decomposition(
        &self,
        k: usize,
        scale: f64,
    ) -> crate::decompose::DecomposableFn {
        crate::decompose::builders::star_components_from_edges(
            self.points.len(),
            &self.knn_edges(k, scale),
            self.unary.clone(),
        )
    }

    /// Star decomposition of the dense [`kernel_cut`](Self::kernel_cut):
    /// per-point stars over the Gaussian affinity plus the label term.
    pub fn kernel_cut_decomposition(&self) -> crate::decompose::DecomposableFn {
        let p = self.points.len();
        let k = self.affinity();
        crate::decompose::builders::star_components(
            p,
            |i, j| k[i * p + j],
            self.unary.clone(),
        )
    }

    /// Paper-exact objective: GP mutual information + label unaries.
    pub fn gaussian_mi(&self, sigma2: f64) -> GaussianMiFn {
        GaussianMiFn::from_points(&self.points, self.params.alpha, sigma2, self.unary.clone())
    }

    /// Fraction of points whose cluster assignment in `a_star` matches the
    /// generating moon (evaluation metric for examples).
    pub fn clustering_accuracy(&self, a_star: &[usize]) -> f64 {
        let p = self.points.len();
        let mut in_a = vec![false; p];
        for &i in a_star {
            in_a[i] = true;
        }
        let correct =
            (0..p).filter(|&i| in_a[i] == (self.moon[i] == 0)).count();
        correct as f64 / p as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::iaes::{solve_sfm_with_screening, IaesOptions};

    #[test]
    fn deterministic_generation() {
        let a = TwoMoons::generate(TwoMoonsParams { p: 50, ..Default::default() });
        let b = TwoMoons::generate(TwoMoonsParams { p: 50, ..Default::default() });
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn label_counts_and_unary_signs() {
        let tm = TwoMoons::generate(TwoMoonsParams { p: 80, ..Default::default() });
        let labeled = tm.labels.iter().filter(|l| l.is_some()).count();
        assert_eq!(labeled, 16);
        for (l, &u) in tm.labels.iter().zip(&tm.unary) {
            match l {
                Some(true) => assert!(u < -10.0, "positive label must pull in"),
                Some(false) => assert!(u > 10.0, "negative label must push out"),
                None => assert!(u.abs() < 1e-12),
            }
        }
    }

    #[test]
    fn affinity_symmetric_in_unit_interval() {
        let tm = TwoMoons::generate(TwoMoonsParams { p: 30, ..Default::default() });
        let k = tm.affinity();
        for i in 0..30 {
            assert_eq!(k[i * 30 + i], 0.0);
            for j in 0..30 {
                assert!(k[i * 30 + j] >= 0.0 && k[i * 30 + j] <= 1.0);
                assert_eq!(k[i * 30 + j], k[j * 30 + i]);
            }
        }
    }

    #[test]
    fn knn_cut_structure() {
        let tm = TwoMoons::generate(TwoMoonsParams { p: 60, ..Default::default() });
        let f = tm.knn_cut(10, 1.0);
        // Degree bounded by mutualized kNN: between k and ~2k edges/vertex.
        let e = f.num_edges();
        assert!(e >= 60 * 10 / 2 && e <= 60 * 10, "edges {e}");
        use crate::submodular::test_support::check_axioms;
        check_axioms(&f, 91, 1e-9);
    }

    #[test]
    fn knn_clustering_beats_chance_at_multiple_sizes() {
        for p in [100usize, 200] {
            let tm = TwoMoons::generate(TwoMoonsParams { p, ..Default::default() });
            let f = tm.knn_cut(10, 1.0);
            let report =
                solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
            let acc = tm.clustering_accuracy(&report.minimizer);
            let acc = acc.max(1.0 - acc);
            assert!(acc > 0.8, "p={p}: accuracy {acc}");
            // Non-degenerate minimizer.
            assert!(report.minimizer.len() > p / 10);
            assert!(report.minimizer.len() < p - p / 10);
        }
    }

    #[test]
    fn clustering_recovers_moons_mostly() {
        // End-to-end sanity: solve the kernel-cut objective on a small
        // instance; the minimizer should align with the moons far better
        // than chance.
        let tm = TwoMoons::generate(TwoMoonsParams { p: 60, seed: 7, ..Default::default() });
        let f = tm.kernel_cut();
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let acc = tm.clustering_accuracy(&report.minimizer);
        let acc = acc.max(1.0 - acc);
        assert!(acc > 0.8, "accuracy only {acc}");
    }
}
