//! 1-D two-component Gaussian mixture model fit by EM.
//!
//! The §4.2 unary potentials come from a GMM over pixel intensities
//! (GrabCut-style [22]): fit one component on foreground seed pixels and
//! one on background seeds, then score every pixel by the log-likelihood
//! ratio. This module implements the EM fit from scratch (no external
//! stats crate in the offline environment).

/// One Gaussian component.
#[derive(Clone, Copy, Debug)]
pub struct Gaussian {
    /// Mean.
    pub mean: f64,
    /// Variance (floored for stability).
    pub var: f64,
    /// Mixture weight.
    pub weight: f64,
}

impl Gaussian {
    /// Log density.
    pub fn log_pdf(&self, x: f64) -> f64 {
        let d = x - self.mean;
        -0.5 * (d * d / self.var) - 0.5 * (2.0 * std::f64::consts::PI * self.var).ln()
    }
}

/// A two-component 1-D mixture.
#[derive(Clone, Copy, Debug)]
pub struct Gmm2 {
    /// The two components.
    pub components: [Gaussian; 2],
}

const VAR_FLOOR: f64 = 1e-6;

impl Gmm2 {
    /// Fit by EM from a deterministic split initialization (below/above the
    /// median). `iters` EM rounds; data must be non-empty.
    pub fn fit(data: &[f64], iters: usize) -> Self {
        assert!(!data.is_empty());
        let mut sorted = data.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let (lo, hi): (Vec<f64>, Vec<f64>) =
            data.iter().partition(|&&x| x <= median);
        let mut comps = [moments(&lo, 0.5), moments(&hi, 0.5)];

        let n = data.len() as f64;
        let mut resp = vec![0.0f64; data.len()];
        for _ in 0..iters {
            // E-step: responsibility of component 0.
            for (r, &x) in resp.iter_mut().zip(data) {
                let l0 = comps[0].weight.max(1e-12).ln() + comps[0].log_pdf(x);
                let l1 = comps[1].weight.max(1e-12).ln() + comps[1].log_pdf(x);
                let m = l0.max(l1);
                let e0 = (l0 - m).exp();
                let e1 = (l1 - m).exp();
                *r = e0 / (e0 + e1);
            }
            // M-step.
            for c in 0..2 {
                let mut wsum = 0.0;
                let mut msum = 0.0;
                for (&r, &x) in resp.iter().zip(data) {
                    let g = if c == 0 { r } else { 1.0 - r };
                    wsum += g;
                    msum += g * x;
                }
                if wsum < 1e-9 {
                    continue; // collapsed component: keep previous params
                }
                let mean = msum / wsum;
                let mut vsum = 0.0;
                for (&r, &x) in resp.iter().zip(data) {
                    let g = if c == 0 { r } else { 1.0 - r };
                    vsum += g * (x - mean) * (x - mean);
                }
                comps[c] = Gaussian {
                    mean,
                    var: (vsum / wsum).max(VAR_FLOOR),
                    weight: wsum / n,
                };
            }
        }
        Gmm2 { components: comps }
    }

    /// Mixture log density.
    pub fn log_pdf(&self, x: f64) -> f64 {
        let l0 = self.components[0].weight.max(1e-12).ln() + self.components[0].log_pdf(x);
        let l1 = self.components[1].weight.max(1e-12).ln() + self.components[1].log_pdf(x);
        let m = l0.max(l1);
        m + ((l0 - m).exp() + (l1 - m).exp()).ln()
    }
}

fn moments(data: &[f64], weight: f64) -> Gaussian {
    if data.is_empty() {
        return Gaussian { mean: 0.0, var: 1.0, weight };
    }
    let n = data.len() as f64;
    let mean = data.iter().sum::<f64>() / n;
    let var = data.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Gaussian { mean, var: var.max(VAR_FLOOR), weight }
}

/// GrabCut-style unary potentials: for each value, `β (log p_bg − log
/// p_fg)` — negative where the foreground model fits better (pulling the
/// pixel *into* the minimizer A = foreground).
pub fn unary_potentials(values: &[f64], fg: &Gmm2, bg: &Gmm2, beta: f64) -> Vec<f64> {
    values.iter().map(|&x| beta * (bg.log_pdf(x) - fg.log_pdf(x))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn em_separates_two_clear_modes() {
        let mut rng = Pcg64::seeded(3);
        let mut data = Vec::new();
        for _ in 0..500 {
            data.push(rng.normal_ms(0.2, 0.05));
        }
        for _ in 0..500 {
            data.push(rng.normal_ms(0.8, 0.05));
        }
        let gmm = Gmm2::fit(&data, 30);
        let mut means: Vec<f64> = gmm.components.iter().map(|c| c.mean).collect();
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((means[0] - 0.2).abs() < 0.03, "mean0 {}", means[0]);
        assert!((means[1] - 0.8).abs() < 0.03, "mean1 {}", means[1]);
    }

    #[test]
    fn log_pdf_integrates_roughly_to_one() {
        let g = Gaussian { mean: 0.0, var: 1.0, weight: 1.0 };
        // Riemann sum over [-6, 6].
        let n = 2000;
        let dx = 12.0 / n as f64;
        let total: f64 =
            (0..n).map(|i| (g.log_pdf(-6.0 + (i as f64 + 0.5) * dx)).exp() * dx).sum();
        assert!((total - 1.0).abs() < 1e-3);
    }

    #[test]
    fn unary_sign_follows_likelihood() {
        let mut rng = Pcg64::seeded(5);
        let fg_data: Vec<f64> = (0..300).map(|_| rng.normal_ms(0.75, 0.06)).collect();
        let bg_data: Vec<f64> = (0..300).map(|_| rng.normal_ms(0.25, 0.06)).collect();
        let fg = Gmm2::fit(&fg_data, 20);
        let bg = Gmm2::fit(&bg_data, 20);
        let u = unary_potentials(&[0.75, 0.25], &fg, &bg, 1.0);
        assert!(u[0] < 0.0, "fg-like pixel must be pulled in");
        assert!(u[1] > 0.0, "bg-like pixel must be pushed out");
    }

    #[test]
    fn fit_handles_constant_data() {
        let data = vec![0.5; 64];
        let gmm = Gmm2::fit(&data, 10);
        assert!(gmm.log_pdf(0.5).is_finite());
    }
}
