//! Experiment workload generators.
//!
//! Reproduces the paper's two experiment families:
//!
//! * [`two_moons`] — the §4.1 synthetic semi-supervised clustering dataset
//!   (two noisy semicircles, 16 labeled points, Gaussian-kernel smoothness
//!   + label unaries), with both the exact GP mutual-information objective
//!   and the fast kernel-cut substitute (DESIGN.md §Substitutions).
//! * [`images`] — §4.2 image segmentation: synthetic foreground/background
//!   scenes standing in for the (unavailable) GrabCut instances, with GMM
//!   unaries ([`gmm`]) and 8-neighbor grid pairwise weights ([`grid`]).
//!
//! All generators are deterministic in their seed.

pub mod gmm;
pub mod grid;
pub mod images;
pub mod two_moons;
