//! 8-neighbor pixel-grid topology helpers (the §4.2 graph structure).

/// Generate the undirected edge list of an `h × w` 8-neighbor grid.
/// Vertices are row-major (`id = r * w + c`); each edge appears once.
pub fn eight_neighbor_edges(h: usize, w: usize) -> Vec<(usize, usize)> {
    let mut edges = Vec::with_capacity(4 * h * w);
    let id = |r: usize, c: usize| r * w + c;
    for r in 0..h {
        for c in 0..w {
            // Right, down, down-right, down-left: covers every undirected
            // 8-neighbor pair exactly once.
            if c + 1 < w {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < h {
                edges.push((id(r, c), id(r + 1, c)));
                if c + 1 < w {
                    edges.push((id(r, c), id(r + 1, c + 1)));
                }
                if c > 0 {
                    edges.push((id(r, c), id(r + 1, c - 1)));
                }
            }
        }
    }
    edges
}

/// Expected 8-neighbor edge count: `(w−1)h + (h−1)w + 2(w−1)(h−1)`.
pub fn eight_neighbor_edge_count(h: usize, w: usize) -> usize {
    if h == 0 || w == 0 {
        return 0;
    }
    (w - 1) * h + (h - 1) * w + 2 * (w - 1) * (h - 1)
}

/// Generate the undirected edge list of a 4-neighbor grid (ablations).
pub fn four_neighbor_edges(h: usize, w: usize) -> Vec<(usize, usize)> {
    let mut edges = Vec::with_capacity(2 * h * w);
    let id = |r: usize, c: usize| r * w + c;
    for r in 0..h {
        for c in 0..w {
            if c + 1 < w {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < h {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_count_formula() {
        for (h, w) in [(1, 1), (2, 2), (3, 5), (10, 7)] {
            assert_eq!(
                eight_neighbor_edges(h, w).len(),
                eight_neighbor_edge_count(h, w),
                "h={h} w={w}"
            );
        }
    }

    #[test]
    fn edges_unique_and_valid() {
        let h = 6;
        let w = 4;
        let edges = eight_neighbor_edges(h, w);
        let mut seen = std::collections::HashSet::new();
        for &(a, b) in &edges {
            assert!(a < h * w && b < h * w && a != b);
            assert!(seen.insert((a.min(b), a.max(b))), "duplicate edge {a}-{b}");
            // 8-neighborhood: |dr| ≤ 1 and |dc| ≤ 1.
            let (ra, ca) = (a / w, a % w);
            let (rb, cb) = (b / w, b % w);
            assert!(ra.abs_diff(rb) <= 1 && ca.abs_diff(cb) <= 1);
        }
    }

    #[test]
    fn four_neighbor_count() {
        assert_eq!(four_neighbor_edges(3, 3).len(), 12);
    }

    #[test]
    fn paper_table2_scale_check() {
        // Table 2: image1 has 50 246 pixels and 201 427 edges — consistent
        // with an (approximately) 8-neighbor grid: edges ≈ 4·pixels.
        let e = eight_neighbor_edge_count(223, 225); // 50 175 px
        let px = 223 * 225;
        let ratio = e as f64 / px as f64;
        assert!(ratio > 3.9 && ratio < 4.0, "ratio {ratio}");
    }
}
