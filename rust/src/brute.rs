//! Brute-force SFM — the exponential ground truth used by safety tests.
//!
//! The minimizers of a submodular function form a lattice (closed under
//! union and intersection), so there is a unique minimal minimizer and a
//! unique maximal minimizer. Theorem 2 identifies them as `{w* > 0}` and
//! `{w* ≥ 0}`; the screening rules are *safe* iff every AES-identified
//! element lies in the minimal minimizer and every IES-identified element
//! lies outside the maximal minimizer. This module computes the whole
//! lattice by enumeration for `p ≤ 24`.

use crate::submodular::Submodular;

/// Exhaustive SFM result.
#[derive(Clone, Debug)]
pub struct BruteResult {
    /// The minimum value of `F`.
    pub minimum: f64,
    /// Intersection of all minimizers (the minimal minimizer).
    pub minimal: Vec<usize>,
    /// Union of all minimizers (the maximal minimizer).
    pub maximal: Vec<usize>,
    /// Number of distinct minimizers.
    pub count: usize,
}

/// Enumerate all `2^p` subsets. `tol` groups values within `tol` of the
/// minimum as co-minimizers (floating-point oracles).
pub fn brute_force_sfm<F: Submodular + ?Sized>(f: &F, tol: f64) -> BruteResult {
    let p = f.ground_size();
    assert!(p <= 24, "brute force limited to p ≤ 24 (got {p})");
    let mut set = vec![false; p];
    let mut minimum = f64::INFINITY;
    // First pass: find the minimum.
    for mask in 0u64..(1u64 << p) {
        for (i, b) in set.iter_mut().enumerate() {
            *b = mask >> i & 1 == 1;
        }
        let v = f.eval(&set);
        if v < minimum {
            minimum = v;
        }
    }
    // Second pass: lattice of minimizers.
    let mut always = vec![true; p];
    let mut ever = vec![false; p];
    let mut count = 0usize;
    for mask in 0u64..(1u64 << p) {
        for (i, b) in set.iter_mut().enumerate() {
            *b = mask >> i & 1 == 1;
        }
        let v = f.eval(&set);
        if v <= minimum + tol {
            count += 1;
            for i in 0..p {
                if set[i] {
                    ever[i] = true;
                } else {
                    always[i] = false;
                }
            }
        }
    }
    BruteResult {
        minimum,
        minimal: (0..p).filter(|&i| always[i]).collect(),
        maximal: (0..p).filter(|&i| ever[i]).collect(),
        count,
    }
}

/// Check that `ids` is a minimizer of `f` (within `tol` of the brute-force
/// minimum). Test helper.
pub fn is_minimizer<F: Submodular + ?Sized>(f: &F, ids: &[usize], tol: f64) -> bool {
    let brute = brute_force_sfm(f, tol);
    let mut setv = vec![false; f.ground_size()];
    for &i in ids {
        setv[i] = true;
    }
    (f.eval(&setv) - brute.minimum).abs() <= tol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::modular::ModularFn;

    #[test]
    fn modular_lattice() {
        // F(A) = w(A): minimizer = all strictly-negative ids; zeros are
        // optional → minimal excludes them, maximal includes them.
        let f = ModularFn::new(vec![-1.0, 0.0, 2.0, -0.5]);
        let r = brute_force_sfm(&f, 1e-12);
        assert_eq!(r.minimum, -1.5);
        assert_eq!(r.minimal, vec![0, 3]);
        assert_eq!(r.maximal, vec![0, 1, 3]);
        assert_eq!(r.count, 2);
    }

    #[test]
    fn lattice_closure_property() {
        // Verify union/intersection of minimizers are minimizers
        // (spot check on a random-ish submodular function).
        let f = IwataFn::new(10);
        let r = brute_force_sfm(&f, 1e-9);
        let mut min_set = vec![false; 10];
        for &i in &r.minimal {
            min_set[i] = true;
        }
        let mut max_set = vec![false; 10];
        for &i in &r.maximal {
            max_set[i] = true;
        }
        assert!((f.eval(&min_set) - r.minimum).abs() < 1e-9);
        assert!((f.eval(&max_set) - r.minimum).abs() < 1e-9);
    }

    #[test]
    fn is_minimizer_helper() {
        let f = ModularFn::new(vec![-1.0, 1.0]);
        assert!(is_minimizer(&f, &[0], 1e-12));
        assert!(!is_minimizer(&f, &[1], 1e-12));
    }
}
