//! Bench-trajectory comparator: diff two `BENCH_*.json` files and fail
//! (exit 1) on any per-op median regression beyond a tolerance.
//!
//! ```sh
//! compare_bench BASELINE.json NEW.json [--tol 0.10]
//! ```
//!
//! Ops present in only one trajectory are ignored (adding or retiring a
//! bench row is not a regression); everything else is matched on
//! `(op, p)` and compared by `median_s`. CI wires this after the micro
//! bench smoke run — see `.github/workflows/ci.yml` and BENCHMARKS.md.

use anyhow::{Context, Result};
use sfm_screen::coordinator::json::Json;
use sfm_screen::coordinator::metrics::{
    compare_bench_records, parse_bench_records, BenchRecord,
};

fn load(path: &str) -> Result<Vec<BenchRecord>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    parse_bench_records(&json).with_context(|| format!("decoding {path}"))
}

fn run(baseline: &str, new: &str, tol: f64) -> Result<bool> {
    let base = load(baseline)?;
    let fresh = load(new)?;
    let matched: Vec<(&str, usize)> = fresh
        .iter()
        .filter(|n| base.iter().any(|b| b.op == n.op && b.p == n.p))
        .map(|n| (n.op.as_str(), n.p))
        .collect();
    let base_only: Vec<(&str, usize)> = base
        .iter()
        .filter(|b| !fresh.iter().any(|n| n.op == b.op && n.p == b.p))
        .map(|b| (b.op.as_str(), b.p))
        .collect();
    let fresh_only: Vec<(&str, usize)> = fresh
        .iter()
        .filter(|n| !base.iter().any(|b| b.op == n.op && b.p == n.p))
        .map(|n| (n.op.as_str(), n.p))
        .collect();
    // Disjoint (op, p) sets mean the gate is comparing nothing — e.g. a
    // baseline recorded at the pinned trajectory sizes vs a smoke run at
    // SFM_BENCH_SIZES=64,128. That's a misconfiguration, not a pass.
    if matched.is_empty() && !base.is_empty() && !fresh.is_empty() {
        anyhow::bail!(
            "no overlapping (op, p) rows between {baseline} and {new} — were the \
             two trajectories recorded at different SFM_BENCH_SIZES?"
        );
    }
    let regressions = compare_bench_records(&base, &fresh, tol);
    println!(
        "compare_bench: {} baseline rows, {} new rows, {} matched, tol {:.0}%",
        base.len(),
        fresh.len(),
        matched.len(),
        tol * 100.0
    );
    // Spell out what the gate actually covered: a thin overlap (most rows
    // skipped on one side) should be visible in the CI log, not inferred.
    for (op, p) in &matched {
        println!("  compared {op}@p={p}");
    }
    if !base_only.is_empty() {
        println!("  skipped {} baseline-only row(s):", base_only.len());
        for (op, p) in &base_only {
            println!("    baseline-only {op}@p={p}");
        }
    }
    if !fresh_only.is_empty() {
        println!("  skipped {} new-only row(s):", fresh_only.len());
        for (op, p) in &fresh_only {
            println!("    new-only {op}@p={p}");
        }
    }
    for r in &regressions {
        println!(
            "REGRESSION {}@p={}: median {:.3e}s -> {:.3e}s ({:+.1}%)",
            r.op,
            r.p,
            r.base_median_s,
            r.new_median_s,
            (r.ratio - 1.0) * 100.0
        );
    }
    if regressions.is_empty() {
        println!("compare_bench: OK — no median regression beyond the gate");
    }
    Ok(regressions.is_empty())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut tol = 0.10;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--tol" {
            let v = it.next().map(|s| s.parse::<f64>());
            match v {
                Some(Ok(t)) if t >= 0.0 => tol = t,
                _ => {
                    eprintln!("compare_bench: --tol needs a non-negative number");
                    std::process::exit(2);
                }
            }
        } else {
            paths.push(a.clone());
        }
    }
    if paths.len() != 2 {
        eprintln!("usage: compare_bench BASELINE.json NEW.json [--tol 0.10]");
        std::process::exit(2);
    }
    match run(&paths[0], &paths[1], tol) {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("compare_bench: {e:#}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use sfm_screen::coordinator::metrics::bench_records_to_json;

    fn write_traj(dir: &std::path::Path, name: &str, medians: &[(&str, f64)]) -> String {
        let records: Vec<BenchRecord> = medians
            .iter()
            .map(|&(op, m)| BenchRecord {
                op: op.to_string(),
                p: 256,
                median_s: m,
                min_s: m,
                ops_per_s: 1.0 / m,
            })
            .collect();
        let path = dir.join(name);
        std::fs::write(&path, bench_records_to_json("micro", &records).to_string())
            .unwrap();
        path.to_string_lossy().into_owned()
    }

    #[test]
    fn self_compare_passes_and_regression_fails() {
        let dir = std::env::temp_dir().join("sfm_compare_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base = write_traj(&dir, "base.json", &[("greedy/cut", 1e-3), ("pav", 2e-3)]);
        let same = run(&base, &base, 0.10).unwrap();
        assert!(same, "self-comparison must pass");
        let slow = write_traj(&dir, "slow.json", &[("greedy/cut", 1.3e-3)]);
        assert!(!run(&base, &slow, 0.10).unwrap(), "30% slowdown must fail");
        let fast = write_traj(&dir, "fast.json", &[("greedy/cut", 0.7e-3)]);
        assert!(run(&base, &fast, 0.10).unwrap(), "speedups must pass");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disjoint_trajectories_are_a_loud_error() {
        // A baseline at different sizes matches nothing — that must fail
        // the gate, not silently pass with 0 comparisons.
        let dir = std::env::temp_dir().join("sfm_compare_bench_disjoint");
        std::fs::create_dir_all(&dir).unwrap();
        let base = write_traj(&dir, "base.json", &[("greedy/cut", 1e-3)]);
        let other = write_traj(&dir, "other.json", &[("pav", 1e-3)]);
        assert!(run(&base, &other, 0.10).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_input_is_an_error() {
        let dir = std::env::temp_dir().join("sfm_compare_bench_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(run(bad.to_str().unwrap(), bad.to_str().unwrap(), 0.1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
