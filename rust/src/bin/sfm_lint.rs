//! `sfm_lint` — the project's invariant lint pass (see LINTS.md).
//!
//! Usage:
//!
//! ```text
//! sfm_lint [--root <dir>]... [--hot] [--json]
//!          [--explain <file-suffix>::<fn>] [--list-rules]
//! ```
//!
//! With no `--root`, lints the crate's own `src/`, `tests/`, and
//! `benches/` directories (located via `CARGO_MANIFEST_DIR` when run
//! through `cargo run --bin sfm_lint`, else the current directory) as
//! one crate — the transitive rules need the whole call graph, so all
//! roots are analyzed together.
//!
//! * `--hot` prints the *computed* transitive hot set (every function
//!   reachable from the hot root set), one `file::fn` per line.
//! * `--explain src/foo.rs::bar` prints the shortest call chain that
//!   makes `bar` hot, or says it is not hot-reachable.
//! * `--json` emits the findings as a JSON array on stdout (one object
//!   per finding: `file`, `line`, `rule`, `code`, `msg`, `chain`);
//!   CI uploads this as the `lint-report` artifact.
//!
//! Exit codes: `0` clean, `1` violations found, `2` usage or I/O error.

use sfm_screen::analysis::callgraph::CallGraph;
use sfm_screen::analysis::{collect_sources, hot_reach, lint_crate, Config, RULES};
use sfm_screen::coordinator::json::Json;
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "sfm_lint [--root <dir>]... [--hot] [--json] \
                     [--explain <file-suffix>::<fn>] [--list-rules]";

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    let cfg = Config::default_for_repo();
    let mut json_out = false;
    let mut print_hot = false;
    let mut explain: Option<(String, String)> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--list-rules" => {
                for (code, name, summary) in RULES {
                    println!("{code}  {name:18} {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(dir) => roots.push(PathBuf::from(dir)),
                None => return usage("--root needs a directory"),
            },
            "--json" => json_out = true,
            "--hot" => print_hot = true,
            "--explain" => {
                let spec = args.next();
                match spec.as_deref().and_then(|s| s.rsplit_once("::")) {
                    Some((f, n)) => explain = Some((f.to_string(), n.to_string())),
                    None => return usage("--explain needs <file-suffix>::<fn>"),
                }
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    if roots.is_empty() {
        let base = std::env::var("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        for sub in ["src", "tests", "benches"] {
            let dir = base.join(sub);
            if dir.is_dir() {
                roots.push(dir);
            }
        }
    }

    let files = match collect_sources(&roots) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("sfm_lint: error reading sources: {e}");
            return ExitCode::from(2);
        }
    };

    if print_hot {
        let graph = CallGraph::build(&files);
        let reach = hot_reach(&graph, &cfg);
        let mut hot: Vec<String> = reach
            .order
            .iter()
            .map(|&i| &graph.fns[i])
            .filter(|f| !f.is_test)
            .map(|f| format!("{}::{}", f.file, f.name))
            .collect();
        hot.sort();
        hot.dedup();
        for line in &hot {
            println!("{line}");
        }
        println!("sfm_lint: {} fns in the transitive hot set", hot.len());
        return ExitCode::SUCCESS;
    }

    if let Some((pat, name)) = explain {
        let graph = CallGraph::build(&files);
        let reach = hot_reach(&graph, &cfg);
        let matches = graph.find(&pat, &name);
        if matches.is_empty() {
            eprintln!("sfm_lint: no fn matching `{pat}::{name}`");
            return ExitCode::from(2);
        }
        for idx in matches {
            let f = &graph.fns[idx];
            if reach.seen[idx] {
                println!("{}::{} is hot — shortest chain:", f.file, f.name);
                for hop in graph.chain(&reach, idx) {
                    println!("    {hop}");
                }
            } else {
                println!("{}::{} is not reachable from the hot root set", f.file, f.name);
            }
        }
        return ExitCode::SUCCESS;
    }

    let diags = lint_crate(&files, &cfg);
    if json_out {
        let arr: Vec<Json> = diags
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::Str(d.file.clone())),
                    ("line", Json::Num(d.line as f64)),
                    ("rule", Json::Str(d.rule.to_string())),
                    ("code", Json::Str(d.code.to_string())),
                    ("msg", Json::Str(d.msg.clone())),
                    (
                        "chain",
                        Json::Arr(d.chain.iter().map(|h| Json::Str(h.clone())).collect()),
                    ),
                ])
            })
            .collect();
        println!("{}", Json::Arr(arr).to_string());
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        if !json_out {
            println!("sfm_lint: {} files clean ({} rules)", files.len(), RULES.len());
        }
        ExitCode::SUCCESS
    } else {
        eprintln!("sfm_lint: {} violation(s) in {} files", diags.len(), files.len());
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("sfm_lint: {msg}");
    eprintln!("usage: {USAGE}");
    ExitCode::from(2)
}
