//! `sfm_lint` — the project's invariant lint pass (see LINTS.md).
//!
//! Usage:
//!
//! ```text
//! sfm_lint [--root <dir>]... [--hot <file-suffix>::<fn>]... [--list-rules]
//! ```
//!
//! With no `--root`, lints the crate's own `src/`, `tests/`, and
//! `benches/` directories (located via `CARGO_MANIFEST_DIR` when run
//! through `cargo run --bin sfm_lint`, else the current directory).
//!
//! Exit codes: `0` clean, `1` violations found, `2` usage or I/O error.

use sfm_screen::analysis::{lint_tree, Config, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut cfg = Config::default_for_repo();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--list-rules" => {
                for (name, summary) in RULES {
                    println!("{name:16} {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(dir) => roots.push(PathBuf::from(dir)),
                None => return usage("--root needs a directory"),
            },
            "--hot" => {
                let spec = args.next();
                match spec.as_deref().and_then(|s| s.split_once("::")) {
                    Some((f, n)) => cfg.hot_fns.push((f.to_string(), n.to_string())),
                    None => return usage("--hot needs <file-suffix>::<fn>"),
                }
            }
            "--help" | "-h" => {
                println!("sfm_lint [--root <dir>]... [--hot <file-suffix>::<fn>]... [--list-rules]");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    if roots.is_empty() {
        let base = std::env::var("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        for sub in ["src", "tests", "benches"] {
            let dir = base.join(sub);
            if dir.is_dir() {
                roots.push(dir);
            }
        }
    }

    let mut total_files = 0usize;
    let mut diags = Vec::new();
    for root in &roots {
        match lint_tree(root, &cfg) {
            Ok((n, d)) => {
                total_files += n;
                diags.extend(d);
            }
            Err(e) => {
                eprintln!("sfm_lint: error reading {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("sfm_lint: {total_files} files clean ({} rules)", RULES.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("sfm_lint: {} violation(s) in {total_files} files", diags.len());
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("sfm_lint: {msg}");
    eprintln!("usage: sfm_lint [--root <dir>]... [--hot <file-suffix>::<fn>]... [--list-rules]");
    ExitCode::from(2)
}
