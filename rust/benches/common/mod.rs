//! Shared bench-harness plumbing (criterion is unavailable offline; each
//! bench is a `harness = false` binary using [`coordinator::metrics`]).
//!
//! Environment knobs:
//!
//! * `SFM_BENCH_FULL=1`  — paper-scale sizes (two-moons 200..1000, ×4 images)
//! * `SFM_BENCH_MI=1`    — exact GP mutual-information two-moons objective
//! * `SFM_BENCH_SIZES=100,200` — explicit two-moons sizes
//! * `SFM_BENCH_BACKEND=rust|xla|auto`
//! * `SFM_BENCH_OUT=dir` — CSV output directory (default `bench_out`)
//! * `SFM_BENCH_EPS`, `SFM_BENCH_RHO`, `SFM_BENCH_SEED`

use sfm_screen::coordinator::experiments::BenchConfig;
use sfm_screen::coordinator::jobs::BackendChoice;

/// Build the bench configuration from the environment.
#[allow(clippy::field_reassign_with_default)]
pub fn config_from_env() -> BenchConfig {
    let mut cfg = BenchConfig::default();
    cfg.quiet = std::env::var("SFM_BENCH_VERBOSE").is_err();
    if env_flag("SFM_BENCH_FULL") {
        cfg = cfg.full();
    }
    if env_flag("SFM_BENCH_MI") {
        cfg.use_mi = true;
        // The exact O(p^3)-per-pass oracle needs smaller defaults.
        if !env_flag("SFM_BENCH_FULL") && std::env::var("SFM_BENCH_SIZES").is_err() {
            cfg.sizes = vec![50, 100, 150, 200];
        }
    }
    if let Ok(s) = std::env::var("SFM_BENCH_SIZES") {
        cfg.sizes = s
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
    }
    if let Ok(b) = std::env::var("SFM_BENCH_BACKEND") {
        cfg.backend = BackendChoice::parse(&b).expect("SFM_BENCH_BACKEND");
    }
    if let Ok(d) = std::env::var("SFM_BENCH_OUT") {
        cfg.out_dir = d.into();
    }
    if let Ok(v) = std::env::var("SFM_BENCH_EPS") {
        cfg.eps = v.parse().expect("SFM_BENCH_EPS");
    }
    if let Ok(v) = std::env::var("SFM_BENCH_RHO") {
        cfg.rho = v.parse().expect("SFM_BENCH_RHO");
    }
    if let Ok(v) = std::env::var("SFM_BENCH_SEED") {
        cfg.seed = v.parse().expect("SFM_BENCH_SEED");
    }
    cfg
}

fn env_flag(name: &str) -> bool {
    matches!(std::env::var(name).as_deref(), Ok("1") | Ok("true") | Ok("yes"))
}

/// Problem sizes for the `micro` bench: an explicit `SFM_BENCH_SIZES` /
/// `SFM_BENCH_FULL` request wins (taken from `cfg.sizes`, which those
/// knobs populate); otherwise the pinned trajectory sizes that the
/// `BENCH_micro.json` regression rows are tracked at. An unparseable or
/// empty `SFM_BENCH_SIZES` falls back to the pinned sizes rather than
/// silently benching nothing.
#[allow(dead_code)] // each bench binary compiles its own copy of this module
pub fn micro_sizes(cfg: &sfm_screen::coordinator::BenchConfig) -> Vec<usize> {
    let explicit = env_flag("SFM_BENCH_FULL")
        || matches!(std::env::var("SFM_BENCH_SIZES"), Ok(ref s) if !s.trim().is_empty());
    if explicit && !cfg.sizes.is_empty() {
        cfg.sizes.clone()
    } else {
        vec![256, 1024, 4096]
    }
}
