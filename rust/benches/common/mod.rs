//! Shared bench-harness plumbing (criterion is unavailable offline; each
//! bench is a `harness = false` binary using [`coordinator::metrics`]).
//!
//! Environment knobs:
//!
//! * `SFM_BENCH_FULL=1`  — paper-scale sizes (two-moons 200..1000, ×4 images)
//! * `SFM_BENCH_MI=1`    — exact GP mutual-information two-moons objective
//! * `SFM_BENCH_SIZES=100,200` — explicit two-moons sizes
//! * `SFM_BENCH_BACKEND=rust|xla|auto`
//! * `SFM_BENCH_OUT=dir` — CSV output directory (default `bench_out`)
//! * `SFM_BENCH_EPS`, `SFM_BENCH_RHO`, `SFM_BENCH_SEED`

use sfm_screen::coordinator::experiments::BenchConfig;
use sfm_screen::coordinator::jobs::BackendChoice;

/// Build the bench configuration from the environment.
pub fn config_from_env() -> BenchConfig {
    let mut cfg = BenchConfig::default();
    cfg.quiet = std::env::var("SFM_BENCH_VERBOSE").is_err();
    if env_flag("SFM_BENCH_FULL") {
        cfg = cfg.full();
    }
    if env_flag("SFM_BENCH_MI") {
        cfg.use_mi = true;
        // The exact O(p^3)-per-pass oracle needs smaller defaults.
        if !env_flag("SFM_BENCH_FULL") && std::env::var("SFM_BENCH_SIZES").is_err() {
            cfg.sizes = vec![50, 100, 150, 200];
        }
    }
    if let Ok(s) = std::env::var("SFM_BENCH_SIZES") {
        cfg.sizes = s
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
    }
    if let Ok(b) = std::env::var("SFM_BENCH_BACKEND") {
        cfg.backend = BackendChoice::parse(&b).expect("SFM_BENCH_BACKEND");
    }
    if let Ok(d) = std::env::var("SFM_BENCH_OUT") {
        cfg.out_dir = d.into();
    }
    if let Ok(v) = std::env::var("SFM_BENCH_EPS") {
        cfg.eps = v.parse().expect("SFM_BENCH_EPS");
    }
    if let Ok(v) = std::env::var("SFM_BENCH_RHO") {
        cfg.rho = v.parse().expect("SFM_BENCH_RHO");
    }
    if let Ok(v) = std::env::var("SFM_BENCH_SEED") {
        cfg.seed = v.parse().expect("SFM_BENCH_SEED");
    }
    cfg
}

fn env_flag(name: &str) -> bool {
    matches!(std::env::var(name).as_deref(), Ok("1") | Ok("true") | Ok("yes"))
}
