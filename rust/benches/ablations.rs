//! Bench: the DESIGN.md ablations.
//!
//! * A1 — trigger frequency ρ (Remark 5): cost/benefit of screening more
//!   or less often.
//! * A2 — rule-pair contributions: ball∩plane (AES-1/IES-1) vs
//!   ball∩annulus (AES-2/IES-2) vs both.
//! * A3 — solver A: min-norm point vs pairwise Frank–Wolfe (Remark 2),
//!   each with and without IAES.
//! * A4 — deferred-contraction threshold (our engineering refinement of
//!   the restart schedule; 0.0 = the literal Algorithm 2).

mod common;

use sfm_screen::coordinator::experiments as exp;
use sfm_screen::coordinator::jobs::WorkloadSpec;
use sfm_screen::coordinator::report::{fnum, Table};
use sfm_screen::screening::RuleSet;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let p = std::env::var("SFM_BENCH_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| *cfg.sizes.last().unwrap_or(&400));

    println!("\nAblation A1 — trigger decay rho (Remark 5), p = {p}");
    let t = exp::ablation_rho(&cfg, p, &[0.1, 0.3, 0.5, 0.7, 0.9])?;
    println!("{}", t.render());

    println!("Ablation A2 — rule-pair contributions, p = {p}");
    let t = exp::ablation_rules(&cfg, p)?;
    println!("{}", t.render());

    println!("Ablation A3 — solver choice (Remark 2), p = {p}");
    let t = exp::ablation_solver(&cfg, p)?;
    println!("{}", t.render());

    println!("Ablation A4 — deferred-contraction threshold, p = {p}");
    let mut t4 = Table::new(&["frac", "wall(s)", "iters", "restarts"]);
    let wl = WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed };
    for frac in [0.0, 0.02, 0.05, 0.1, 0.2, 0.5] {
        let mut c = cfg.clone();
        c.min_reduction_frac = frac;
        let run = exp::run_variant(&wl, RuleSet::all(), &c)?;
        // Restarts = triggers that actually contracted (p_before changes).
        let mut restarts = 0;
        let mut last_p = None;
        for tr in &run.report.triggers {
            if last_p.is_some() && last_p != Some(tr.p_before) {
                restarts += 1;
            }
            last_p = Some(tr.p_before);
        }
        t4.push_row(vec![
            fnum(frac),
            fnum(run.wall.as_secs_f64()),
            run.report.iters.to_string(),
            restarts.to_string(),
        ]);
    }
    t4.write_csv(cfg.out_dir.join("ablation_contraction.csv"))?;
    println!("{}", t4.render());
    println!("CSV: {}/ablation_*.csv", cfg.out_dir.display());
    Ok(())
}
