//! Micro-benchmarks of the hot paths — the §Perf evidence base.
//!
//! * greedy `prefix_gains` oracle throughput per function family, both the
//!   zero-allocation workspace path (`greedy/*`) and the allocating
//!   reference path (`greedy/*-alloc`) so the speedup of the flat/scratch
//!   engine is measurable from a single run,
//! * one full min-norm major iteration (greedy + corral update),
//! * PAV refinement,
//! * screening-rule evaluation: rust backend vs the AOT XLA kernel
//!   (quantifies the PJRT call-overhead crossover discussed in
//!   EXPERIMENTS.md §Perf).
//!
//! Besides the terminal table and `micro.csv`, this bench writes the
//! machine-readable `BENCH_micro.json` trajectory at the repo root
//! (override the directory with `SFM_BENCH_JSON_DIR`) — the regression
//! baseline for subsequent PRs. See BENCHMARKS.md for the schema.

mod common;

use sfm_screen::coordinator::metrics::{
    bench, fmt_duration, write_bench_json, BenchRecord, Summary,
};
use sfm_screen::coordinator::report::Table;
use sfm_screen::decompose::builders::{grid_cut_components, star_components_from_edges};
use sfm_screen::decompose::chain::{tv_prox_into, TautStringWorkspace};
use sfm_screen::decompose::{BlockProxSolver, DecomposeOptions};
use sfm_screen::linalg::vecops::{argsort_desc, argsort_desc_into, argsort_desc_remap};
use sfm_screen::linalg::{IncrementalCholesky, Mat};
use sfm_screen::lovasz::{
    greedy_base_vertex, greedy_base_vertex_ref, ContractionMap, GreedyWorkspace,
};
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::rules::RustScreener;
use sfm_screen::screening::{RuleSet, ScreenInputs, Screener};
use sfm_screen::solvers::minnorm::{MinNormOptions, MinNormPoint};
use sfm_screen::solvers::pav::pav_nonincreasing_into;
use sfm_screen::solvers::ProxSolver;
use sfm_screen::submodular::scaled::ScaledFn;
use sfm_screen::submodular::Submodular;
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};
use std::time::Duration;

struct Rows {
    table: Table,
    records: Vec<BenchRecord>,
}

impl Rows {
    fn new() -> Self {
        Rows {
            table: Table::new(&["op", "p", "median", "min", "ops/s"]),
            records: Vec::new(),
        }
    }

    fn push(&mut self, op: &str, p: usize, s: &Summary) {
        self.table.push_row(vec![
            op.into(),
            p.to_string(),
            fmt_duration(Duration::from_secs_f64(s.median)),
            fmt_duration(Duration::from_secs_f64(s.min)),
            format!("{:.1}", 1.0 / s.median),
        ]);
        self.records.push(BenchRecord::new(op, p, s));
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let mut rows = Rows::new();
    let mut rng = Pcg64::seeded(77);

    // Default sizes pin the regression-tracked rows (p = 4096 rows are the
    // PR-1 acceptance baseline); SFM_BENCH_SIZES/SFM_BENCH_FULL override
    // for smoke or paper-scale runs (resolved centrally in `common`).
    let sizes = common::micro_sizes(&cfg);
    for &p in &sizes {
        let tm = TwoMoons::generate(TwoMoonsParams { p, ..Default::default() });

        // Greedy pass: dense kernel cut (O(p²)) and sparse kNN cut (O(pk)),
        // each as the workspace-reusing fast path and the allocating
        // reference (fresh buffers + full sort every call).
        let dense = tm.kernel_cut();
        // One kNN neighbor search (O(p²)) serves both the monolithic cut
        // and its star decomposition below.
        let knn_edges = tm.knn_edges(10, 1.0);
        let sparse = sfm_screen::submodular::cut::CutFn::from_edges(
            p,
            &knn_edges,
            tm.unary.clone(),
        );
        let w = rng.normal_vec(p);
        let mut ws = GreedyWorkspace::new(p);
        let mut s_out = vec![0.0; p];
        // The pool-less workspace rows ARE the t = 1 leg of the pooled
        // monolithic oracle: each is recorded under both its historical
        // id and the explicit `-t1` schema id from ONE measurement (no
        // double benching, and compare_bench gates each quantity once
        // per name — the duplicate-named rows track identical numbers).
        let (sum, _) = bench(3, 10, || {
            greedy_base_vertex(&dense, &w, &mut ws, &mut s_out);
            s_out[0]
        });
        rows.push("greedy/kernel-cut", p, &sum);
        rows.push("greedy/kernel-cut-t1", p, &sum);
        let (sum, _) = bench(3, 10, || {
            greedy_base_vertex_ref(&dense, &w, &mut s_out);
            s_out[0]
        });
        rows.push("greedy/kernel-cut-alloc", p, &sum);
        let (sum, _) = bench(3, 20, || {
            greedy_base_vertex(&sparse, &w, &mut ws, &mut s_out);
            s_out[0]
        });
        rows.push("greedy/cut", p, &sum);
        rows.push("greedy/cut-t1", p, &sum);
        let (sum, _) = bench(3, 20, || {
            greedy_base_vertex_ref(&sparse, &w, &mut s_out);
            s_out[0]
        });
        rows.push("greedy/cut-alloc", p, &sum);

        // Pooled monolithic greedy rows (greedy/*-t4): the same passes
        // at t = 4 — 3 parked workers + the bench thread, the monolithic
        // `--threads 4` convention. The pooled pass is bit-identical to
        // the t1 rows above; the t4/t1 delta is pure wall clock from the
        // worker fan-out (`greedy/kernel-cut p=4096` scaling with cores
        // is the ROADMAP target).
        {
            use sfm_screen::runtime::pool::WorkerPool;
            use std::sync::Arc;
            let pool = Arc::new(WorkerPool::new(3));
            let mut ws_t4 = GreedyWorkspace::new(p);
            ws_t4.set_pool(Some(Arc::clone(&pool)));
            let (sum, _) = bench(3, 10, || {
                greedy_base_vertex(&dense, &w, &mut ws_t4, &mut s_out);
                s_out[0]
            });
            rows.push("greedy/kernel-cut-t4", p, &sum);
            let (sum, _) = bench(3, 20, || {
                greedy_base_vertex(&sparse, &w, &mut ws_t4, &mut s_out);
                s_out[0]
            });
            rows.push("greedy/cut-t4", p, &sum);
        }

        // One min-norm major iteration on the sparse objective.
        let mut solver = MinNormPoint::new(&sparse, MinNormOptions::default(), None);
        let (sum, _) = bench(3, 20, || solver.step(&sparse).gap);
        rows.push("minnorm-iter", p, &sum);

        // Contraction restart (restart/* rows, schema in BENCHMARKS.md):
        // each rep runs one IAES-style cycle — cold rebuild at full size,
        // 5 major iterations, drop 20% of the elements, restart. The
        // `warm` row projects the corral through the survivor map
        // (`reset_mapped`); the `cold` row discards it (`set_reduction` +
        // `reset`). The shared prefix is identical, so the row delta is
        // the restart cost itself.
        let kept_full: Vec<usize> = (0..p).collect();
        let kept_small: Vec<usize> = (0..p).filter(|&i| i % 5 != 0).collect();
        let mut scaled = ScaledFn::new(&sparse, &[], kept_full.clone());
        let mut rsolver = MinNormPoint::new(&scaled, MinNormOptions::default(), None);
        let w0 = vec![0.0; p];
        let mut map = ContractionMap::new();
        let mut w_surv: Vec<f64> = Vec::new();
        let (sum, _) = bench(1, 10, || {
            scaled.set_reduction(&[], &kept_full);
            rsolver.reset(&scaled, &w0);
            for _ in 0..5 {
                rsolver.step(&scaled);
            }
            w_surv.clear();
            w_surv.extend(kept_small.iter().map(|&i| rsolver.w()[i]));
            scaled.contract(&[], &kept_small, &mut map);
            rsolver.reset_mapped(&scaled, &w_surv, &map);
            rsolver.gap()
        });
        rows.push("restart/warm", p, &sum);
        let (sum, _) = bench(1, 10, || {
            scaled.set_reduction(&[], &kept_full);
            rsolver.reset(&scaled, &w0);
            for _ in 0..5 {
                rsolver.step(&scaled);
            }
            w_surv.clear();
            w_surv.extend(kept_small.iter().map(|&i| rsolver.w()[i]));
            scaled.set_reduction(&[], &kept_small);
            rsolver.reset(&scaled, &w_surv);
            rsolver.gap()
        });
        rows.push("restart/cold", p, &sum);

        // Post-contraction greedy argsort: survivor remap + O(p) repair
        // vs the full re-sort it replaces.
        let w_old = rng.normal_vec(p);
        let idx_old = argsort_desc(&w_old);
        let mut new_of_old = vec![usize::MAX; p];
        let mut w_new = Vec::new();
        for (i, &x) in w_old.iter().enumerate() {
            if i % 5 != 0 {
                new_of_old[i] = w_new.len();
                w_new.push(x);
            }
        }
        let mut idx = idx_old.clone();
        let (sum, _) = bench(3, 30, || {
            idx.clone_from(&idx_old);
            argsort_desc_remap(&w_new, &mut idx, &new_of_old);
            idx[0]
        });
        rows.push("restart/argsort-remap", p, &sum);
        let (sum, _) = bench(3, 30, || {
            argsort_desc_into(&w_new, &mut idx);
            idx[0]
        });
        rows.push("restart/argsort-full", p, &sum);

        // Decomposable block solver, §4.1 family (decompose/star-*):
        // one best-response round (parallel per-point star prox solves +
        // the global certificate pass) on the same kNN objective as the
        // minnorm-iter row, at fixed thread counts so the trajectory
        // stays comparable across machines.
        let star_dec = star_components_from_edges(p, &knn_edges, tm.unary.clone());
        for t in [1usize, 2] {
            let mut bsolver = BlockProxSolver::new(
                &star_dec,
                DecomposeOptions { threads: t, ..Default::default() },
            );
            let (sum, _) = bench(1, 5, || bsolver.step(&star_dec).gap);
            rows.push(&format!("decompose/star-round-t{t}"), p, &sum);
        }

        // Translated warm duals (decompose/warm-dual-cycle vs the cold
        // in-run control): generic star components carry their min-norm
        // corral across rounds by translating atoms with the modular-
        // shift delta; the cold row regenerates every block solve from
        // one vertex (the PR-3 behaviour). Same objective, same rounds —
        // the row delta is the warm-start saving itself.
        let mut warm_solver = BlockProxSolver::new(
            &star_dec,
            DecomposeOptions { threads: 1, ..Default::default() },
        );
        let (sum, _) = bench(1, 5, || warm_solver.step(&star_dec).gap);
        rows.push("decompose/warm-dual-cycle", p, &sum);
        let mut cold_solver = BlockProxSolver::new(
            &star_dec,
            DecomposeOptions { threads: 1, warm_duals: false, ..Default::default() },
        );
        let (sum, _) = bench(1, 5, || cold_solver.step(&star_dec).gap);
        rows.push("decompose/cold-dual-cycle", p, &sum);

        // Chain prox (decompose/chain-prox): one O(p) taut-string TV
        // prox + dual recovery on a p-length chain — the closed form that
        // replaced the per-chain min-norm solver for grid components.
        let tvals = rng.normal_vec(p);
        let lams: Vec<f64> = (0..p - 1).map(|_| rng.uniform(0.0, 1.0)).collect();
        let mut tv_ws = TautStringWorkspace::default();
        let mut tv_x = vec![0.0; p];
        let (sum, _) = bench(3, 50, || {
            tv_prox_into(&tvals, &lams, &mut tv_ws, &mut tv_x);
            // Dual recovery: y = t − x (read off the bends).
            let mut y0 = 0.0;
            for (xv, tv) in tv_x.iter().zip(&tvals) {
                y0 += tv - xv;
            }
            y0
        });
        rows.push("decompose/chain-prox", p, &sum);

        // PAV refinement.
        let t = rng.normal_vec(p);
        let mut out = vec![0.0; p];
        let (sum, _) = bench(3, 50, || {
            pav_nonincreasing_into(&t, &mut out);
            out[0]
        });
        rows.push("pav", p, &sum);

        // Screening rules: rust vs xla.
        let wv = rng.normal_vec(p);
        let gap = 0.3;
        let f_v = -wv.iter().sum::<f64>();
        let inputs = ScreenInputs { w: &wv, gap, f_v, f_c: -0.4 };
        let rust = RustScreener::default();
        let (sum, _) = bench(3, 50, || rust.screen(&inputs, RuleSet::all()).identified());
        rows.push("screen/rust", p, &sum);
        if let Ok(xla) = sfm_screen::runtime::XlaScreener::at_default() {
            let _ = xla.screen(&inputs, RuleSet::all()); // compile warmup
            let (sum, _) =
                bench(3, 30, || xla.screen(&inputs, RuleSet::all()).identified());
            rows.push("screen/xla", p, &sum);
        }
    }

    // Decomposable block solver, §4.2 family (decompose/grid-*): a g×g
    // 8-neighbor grid cut decomposed into row/column/diagonal chains +
    // unary, one best-response round per rep, vs one monolithic min-norm
    // iteration on the identical objective. Fixed t1/t2 rows are the
    // regression-tracked pair; SFM_BENCH_THREADS=N adds a custom-count
    // row for thread-scaling sweeps (not baseline-compared — core counts
    // differ across machines).
    for &p in &sizes {
        let g = (p as f64).sqrt().round().max(2.0) as usize;
        let (h, w) = (g, g);
        let mut grng = Pcg64::seeded(4321);
        let edges: Vec<(usize, usize, f64)> =
            sfm_screen::workloads::grid::eight_neighbor_edges(h, w)
                .into_iter()
                .map(|(a, b)| (a, b, grng.uniform(0.0, 1.0)))
                .collect();
        let unary = grng.uniform_vec(h * w, -1.0, 1.0);
        let mono = sfm_screen::submodular::cut::CutFn::from_edges(
            h * w,
            &edges,
            unary.clone(),
        );
        let dec = grid_cut_components(h, w, &edges, unary)?;
        let mut msolver = MinNormPoint::new(&mono, MinNormOptions::default(), None);
        let (sum, _) = bench(3, 10, || msolver.step(&mono).gap);
        rows.push("decompose/grid-mono-iter", h * w, &sum);
        let mut tcounts = vec![1usize, 2];
        if let Ok(tv) = std::env::var("SFM_BENCH_THREADS") {
            if let Ok(tv) = tv.trim().parse::<usize>() {
                if tv > 0 && !tcounts.contains(&tv) {
                    tcounts.push(tv);
                }
            }
        }
        for t in tcounts {
            let mut bsolver = BlockProxSolver::new(
                &dec,
                DecomposeOptions { threads: t, ..Default::default() },
            );
            let (sum, _) = bench(1, 5, || bsolver.step(&dec).gap);
            rows.push(&format!("decompose/grid-round-t{t}"), h * w, &sum);
        }
        // Explicit Gauss–Seidel rows (decompose/gs-round-t{1,4}): the
        // group-scheduled sweep pinned at 1 and 4 workers regardless of
        // future default flips — t4 exercises the parked worker pool.
        for t in [1usize, 4] {
            let mut bsolver = BlockProxSolver::new(
                &dec,
                DecomposeOptions { threads: t, gauss_seidel: true, ..Default::default() },
            );
            let (sum, _) = bench(1, 5, || bsolver.step(&dec).gap);
            rows.push(&format!("decompose/gs-round-t{t}"), h * w, &sum);
        }
    }

    // SIMD vector-kernel rows (vecops/*): the 4-lane unrolled primitives
    // the oracle gains paths route through, at fixed sizes independent
    // of SFM_BENCH_SIZES (the kernels are size-stable; p here is the
    // vector length). `sweep4` is the bandwidth-bound kernel-cut inner
    // loop, `dot-gather4` the sparse-cut adjacency walk.
    {
        use sfm_screen::linalg::vecops::{axpy4, dot4, dot_gather4, sweep4};
        for &n in &[4096usize, 65536] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let (sum, _) = bench(5, 60, || dot4(&a, &b));
            rows.push("vecops/dot4", n, &sum);
            let mut y = rng.normal_vec(n);
            let (sum, _) = bench(5, 60, || {
                axpy4(1e-9, &a, &mut y);
                y[0]
            });
            rows.push("vecops/axpy4", n, &sum);
            let r0 = rng.normal_vec(n);
            let r1 = rng.normal_vec(n);
            let r2 = rng.normal_vec(n);
            let r3 = rng.normal_vec(n);
            let mut acc = vec![0.0; n];
            let (sum, _) = bench(5, 60, || {
                sweep4(&mut acc, &r0, &r1, &r2, &r3);
                acc[0]
            });
            rows.push("vecops/sweep4", n, &sum);
            let idx: Vec<u32> = (0..n as u32).rev().collect();
            let (sum, _) = bench(5, 60, || dot_gather4(&a, &idx, &b));
            rows.push("vecops/dot-gather4", n, &sum);
        }
    }

    // Queyranne baseline (combinatorial; requires symmetric F, so use the
    // unlabeled two-moons cut — zero unaries).
    for &p in &[32usize, 64] {
        let tm =
            TwoMoons::generate(TwoMoonsParams { p, labeled: 0, ..Default::default() });
        let f = tm.knn_cut(10, 1.0);
        let (sum, _) = bench(1, 3, || {
            sfm_screen::solvers::queyranne::queyranne(&f).minimum
        });
        rows.push("queyranne/sym-cut", p, &sum);
    }

    // Batched corral-Gram downdate (restart/chol-*): retain() compacting
    // 12 evictions in one sweep vs 12 sequential remove() calls, at a
    // representative corral size. Both reps clone the base factor, so the
    // row delta is the downdate itself.
    {
        let m = 96usize;
        let mut srng = Pcg64::seeded(4242);
        let g = Mat::from_fn(m, m, |_, _| srng.normal());
        let mut a = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                let mut s = 0.0;
                for k in 0..m {
                    s += g[(i, k)] * g[(j, k)];
                }
                a[(i, j)] = s + if i == j { m as f64 } else { 0.0 };
            }
        }
        let mut base = IncrementalCholesky::with_capacity(m);
        for i in 0..m {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            base.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let keep: Vec<usize> = (0..m).filter(|i| i % 8 != 0).collect();
        let drop: Vec<usize> = (0..m).filter(|i| i % 8 == 0).collect();
        let (sum, _) = bench(3, 30, || {
            let mut c = base.clone();
            c.retain(&keep);
            c.dim()
        });
        rows.push("restart/chol-retain", m, &sum);
        let (sum, _) = bench(3, 30, || {
            let mut c = base.clone();
            for &k in drop.iter().rev() {
                c.remove(k);
            }
            c.dim()
        });
        rows.push("restart/chol-remove-seq", m, &sum);
    }

    // Gaussian-MI oracle (the paper-exact objective) at small p.
    for &p in &[64usize, 128] {
        let tm = TwoMoons::generate(TwoMoonsParams { p, ..Default::default() });
        let mi = tm.gaussian_mi(0.1);
        let w = rng.normal_vec(p);
        let mut ws = GreedyWorkspace::new(p);
        let mut s_out = vec![0.0; p];
        let (sum, _) = bench(1, 5, || {
            greedy_base_vertex(&mi, &w, &mut ws, &mut s_out);
            s_out[0]
        });
        rows.push("greedy/gp-mi", p, &sum);
        let _ = mi.ground_size();
    }

    // Resident-service rows (serve/*): end-to-end job turnaround through
    // the serve core — parse, admission, solve, response serialization.
    // `-cold` spins up a fresh service (and builds the oracle) per job;
    // `-cached` reuses one resident service whose instance cache already
    // holds the workload, so the cold/cached delta is the construction
    // cost the cache removes; `cancel-latency` is the round trip for a
    // job whose deadline has already expired at admission — the floor on
    // how fast the service turns a cancellation into a partial report.
    {
        use sfm_screen::coordinator::serve::{ServeCore, ServeOptions};
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        #[derive(Clone)]
        struct CountingSink(Arc<AtomicUsize>);
        impl std::io::Write for CountingSink {
            fn write(&mut self, d: &[u8]) -> std::io::Result<usize> {
                let n = d.iter().filter(|&&b| b == b'\n').count();
                self.0.fetch_add(n, Ordering::Release);
                Ok(d.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        let p = 128usize;
        let line =
            format!(r#"{{"workload": {{"kind": "two-moons", "p": {p}, "seed": 5}}}}"#);
        let (sum, _) = bench(1, 5, || {
            let count = Arc::new(AtomicUsize::new(0));
            let core = ServeCore::start(
                &ServeOptions::default(),
                Box::new(CountingSink(Arc::clone(&count))),
            );
            core.submit_line(&line);
            core.finish();
            count.load(Ordering::Acquire)
        });
        rows.push("serve/throughput-cold", p, &sum);

        let count = Arc::new(AtomicUsize::new(0));
        let core = ServeCore::start(
            &ServeOptions::default(),
            Box::new(CountingSink(Arc::clone(&count))),
        );
        let wait_past = |n: usize| {
            while count.load(Ordering::Acquire) <= n {
                std::thread::yield_now();
            }
        };
        core.submit_line(&line); // prime the instance cache
        wait_past(0);
        let (sum, _) = bench(2, 10, || {
            let before = count.load(Ordering::Acquire);
            core.submit_line(&line);
            wait_past(before);
            before
        });
        rows.push("serve/throughput-cached", p, &sum);

        let cancel_line = format!(
            r#"{{"deadline_ms": 0, "workload": {{"kind": "two-moons", "p": {p}, "seed": 5}}}}"#
        );
        let (sum, _) = bench(2, 10, || {
            let before = count.load(Ordering::Acquire);
            core.submit_line(&cancel_line);
            wait_past(before);
            before
        });
        rows.push("serve/cancel-latency", p, &sum);

        // Stats turnaround: `{"op": "stats"}` is answered synchronously
        // on the submitting thread (never queued), so this row is the
        // pure registry-snapshot + serialization cost — the floor on how
        // cheaply a scraper can poll a loaded service.
        let stats_line = r#"{"op": "stats"}"#;
        let (sum, _) = bench(2, 50, || {
            let before = count.load(Ordering::Acquire);
            core.submit_line(stats_line);
            wait_past(before);
            before
        });
        rows.push("serve/stats-latency", p, &sum);
        core.finish();
    }

    // Observability rows (obs/*): the identical IAES solve with and
    // without an attached trace sink. An attached sink adds one clock
    // read per phase span and one mutex round-trip per major iteration;
    // the traced/untraced median delta — the `obs/trace-overhead`
    // budget — must stay ≤ 2% (OBSERVABILITY.md). Both rows run the
    // same instance, so the pair is directly comparable within one run.
    {
        use sfm_screen::obs::TraceSink;
        use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
        let p = 256usize;
        let tm = TwoMoons::generate(TwoMoonsParams { p, ..Default::default() });
        let dense = tm.kernel_cut();
        let opts = |trace: Option<TraceSink>| IaesOptions {
            record_history: false,
            trace,
            ..Default::default()
        };
        let untraced = opts(None);
        let (sum, _) = bench(2, 10, || {
            solve_sfm_with_screening(&dense, &untraced).unwrap().minimum
        });
        rows.push("obs/solve-untraced", p, &sum);
        let traced = opts(Some(TraceSink::new()));
        let (sum, _) = bench(2, 10, || {
            solve_sfm_with_screening(&dense, &traced).unwrap().minimum
        });
        rows.push("obs/trace-overhead", p, &sum);
    }

    println!("\nMicro-benchmarks (hot paths)");
    println!("{}", rows.table.render());
    rows.table.write_csv(cfg.out_dir.join("micro.csv"))?;
    println!("CSV: {}", cfg.out_dir.join("micro.csv").display());
    let json_path = write_bench_json("micro", &rows.records)?;
    println!("JSON trajectory: {}", json_path.display());
    Ok(())
}
