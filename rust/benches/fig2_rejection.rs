//! Bench: **Figure 2** — rejection ratios of IAES over iterations on
//! two-moons, one CSV per problem size (`bench_out/fig2_p{p}.csv`).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let table = sfm_screen::coordinator::experiments::fig2(&cfg)?;
    println!("\nFigure 2 — rejection ratio curves (summary)");
    println!("{}", table.render());
    println!("CSV curves: {}/fig2_p*.csv", cfg.out_dir.display());
    Ok(())
}
