//! Bench: **Figure 3** — visualization of the IAES screening process on
//! two-moons (p = 400): point status (active / inactive / unknown) after
//! every trigger, one CSV per snapshot (`bench_out/fig3_step{k}.csv`).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let p = std::env::var("SFM_BENCH_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let table = sfm_screen::coordinator::experiments::fig3(&cfg, p)?;
    println!("\nFigure 3 — screening process snapshots (p = {p})");
    println!("{}", table.render());
    println!("CSV snapshots: {}/fig3_step*.csv", cfg.out_dir.display());
    Ok(())
}
