//! Bench: **Figure 4** — rejection ratios of IAES over iterations on the
//! five image-segmentation instances (`bench_out/fig4_image*.csv`).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let table = sfm_screen::coordinator::experiments::fig4(&cfg)?;
    println!("\nFigure 4 — rejection ratio curves on images (summary)");
    println!("{}", table.render());
    println!("CSV curves: {}/fig4_image*.csv", cfg.out_dir.display());
    Ok(())
}
