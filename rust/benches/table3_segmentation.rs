//! Bench: **Tables 2 + 3** — image-segmentation statistics and running
//! times (synthetic GrabCut stand-ins; DESIGN.md §Substitutions).
//!
//! ```bash
//! cargo bench --bench table3_segmentation
//! SFM_BENCH_FULL=1 cargo bench --bench table3_segmentation  # ~paper pixel counts
//! ```

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    let (t2, t3) = sfm_screen::coordinator::experiments::table3(&cfg)?;
    println!("\nTable 2 — image segmentation instance statistics");
    println!("{}", t2.render());
    println!("Table 3 — running time (seconds) & speedups");
    println!("{}", t3.render());
    println!(
        "CSV: {} and {}",
        cfg.out_dir.join("table2.csv").display(),
        cfg.out_dir.join("table3.csv").display()
    );
    Ok(())
}
