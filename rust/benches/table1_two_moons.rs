//! Bench: **Table 1** — running time for SFM on two-moons.
//!
//! Regenerates the paper's Table 1 rows (MinNorm vs AES+ / IES+ /
//! IAES+MinNorm with per-variant screening cost and speedups). CSV lands
//! in `bench_out/table1.csv`.
//!
//! ```bash
//! cargo bench --bench table1_two_moons            # scaled-down sizes
//! SFM_BENCH_FULL=1 cargo bench --bench table1_two_moons   # paper sizes
//! SFM_BENCH_MI=1   cargo bench --bench table1_two_moons   # exact GP-MI objective
//! ```

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::config_from_env();
    println!("\nTable 1 — two-moons running time (seconds) & speedups");
    println!(
        "objective: {}, eps = {:.0e}, rho = {}, backend = {:?}\n",
        if cfg.use_mi { "GP mutual information (paper-exact)" } else { "kNN Gaussian cut" },
        cfg.eps,
        cfg.rho,
        cfg.backend
    );
    let table = sfm_screen::coordinator::experiments::table1(&cfg)?;
    println!("{}", table.render());
    println!("CSV: {}", cfg.out_dir.join("table1.csv").display());
    Ok(())
}
